//! Property-based tests over the pure-Rust L3 substrates.
//!
//! The offline image has no proptest crate, so this file carries a small
//! seeded-random property harness (`cases`): each property runs across a
//! few hundred randomized cases drawn from `profl::rng::Rng`; failures
//! print the case seed for deterministic replay.

use profl::aggregate::{
    staleness_discount, transition_decay, Aggregator, BufferedAggregator, SlicedAggregator,
    TensorPool,
};
use profl::RunConfig;
use profl::checkpoint::{Checkpoint, Dec, MidPhase};
use profl::clients::{ClientCkpt, ClientPool, LazyCkpt, PoolCkptKind, PoolCkptState};
use profl::coordinator::projection::{project_tensors, TrainableLayout};
use profl::coordinator::PendingUpdate;
use profl::data::{partition, Partition, SyntheticDataset};
use profl::fleet::{
    simulate_round, AvailabilityTrace, ChurnPolicy, ClientWork, EventKind, FleetEngine,
    RoundPolicy,
};
use profl::freezing::{ls_slope, DetectorSnapshot, EffectiveMovement, Transition};
use profl::json::Value;
use profl::manifest::MemCoeffs;
use profl::memory::{can_train, DeviceMemory, MemoryConfig};
use profl::metrics::RoundRecord;
use profl::rng::Rng;
use profl::store::{ParamStore, Tensor};
use profl::strategy::{
    depth_cap, elastic, layout_mem, strategy_for_resume, BlockLayout, DistillPhase, ModelView,
    Phase, StepFeedback, TrainPhase,
};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Run `f` over `n` seeded cases; panics include the failing seed.
fn cases(n: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0xabcd_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property failed at case seed {seed}: {e:?}");
        }
    }
}

fn rand_shape(rng: &mut Rng) -> Vec<usize> {
    let rank = 1 + rng.below(3);
    (0..rank).map(|_| 1 + rng.below(6)).collect()
}

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Vec<f32> {
    (0..shape.iter().product::<usize>()).map(|_| rng.normal()).collect()
}

fn store_with(name: &str, shape: &[usize], data: Vec<f32>) -> ParamStore {
    let shapes: BTreeMap<String, Vec<usize>> = [(name.to_string(), shape.to_vec())].into();
    let mut s = ParamStore::init(&shapes, 0);
    s.set(name, Tensor { shape: shape.to_vec(), data });
    s
}

// ---------------------------------------------------------------------------
// FedAvg aggregation invariants (Eq. 1)
// ---------------------------------------------------------------------------

#[test]
fn prop_aggregate_within_envelope() {
    // The weighted mean of client updates is bounded by their min/max.
    cases(200, |rng| {
        let shape = rand_shape(rng);
        let n: usize = shape.iter().product();
        let mut store = store_with("w", &shape, vec![0.0; n]);
        let names = vec!["w".to_string()];
        let mut agg = Aggregator::new(&names, &store).unwrap();
        let k = 1 + rng.below(5);
        let mut lo = vec![f32::MAX; n];
        let mut hi = vec![f32::MIN; n];
        for _ in 0..k {
            let t = rand_tensor(rng, &shape);
            for i in 0..n {
                lo[i] = lo[i].min(t[i]);
                hi[i] = hi[i].max(t[i]);
            }
            agg.add(&[t], rng.uniform(0.1, 10.0));
        }
        agg.finish(&mut store).unwrap();
        let out = &store.get("w").unwrap().data;
        for i in 0..n {
            assert!(out[i] >= lo[i] - 1e-4 && out[i] <= hi[i] + 1e-4, "i={i}");
        }
    });
}

#[test]
fn prop_buffered_staleness_merge_stays_in_envelope() {
    // A staleness-discounted weighted mean is still a convex combination:
    // whatever the alpha/staleness mix, the merge stays inside the
    // per-position min/max envelope of the contributing updates, and the
    // total weight equals the sum of discounted weights.
    cases(150, |rng| {
        let shape = rand_shape(rng);
        let n: usize = shape.iter().product();
        let mut store = store_with("w", &shape, vec![0.0; n]);
        let names = vec!["w".to_string()];
        let alpha = rng.uniform(0.0, 2.0);
        let mut agg = BufferedAggregator::new(&names, &store, alpha).unwrap();
        let k = 1 + rng.below(5);
        let mut lo = vec![f32::MAX; n];
        let mut hi = vec![f32::MIN; n];
        let mut expect_w = 0.0f64;
        for _ in 0..k {
            let t = rand_tensor(rng, &shape);
            for i in 0..n {
                lo[i] = lo[i].min(t[i]);
                hi[i] = hi[i].max(t[i]);
            }
            let w = rng.uniform(0.1, 10.0);
            let staleness = rng.below(6);
            expect_w += w * staleness_discount(staleness, alpha);
            agg.add(&[t], w, staleness);
        }
        assert_eq!(agg.merged(), k);
        assert!((agg.total_weight() - expect_w).abs() < 1e-9);
        agg.finish(&mut store).unwrap();
        let out = &store.get("w").unwrap().data;
        for i in 0..n {
            assert!(out[i] >= lo[i] - 1e-4 && out[i] <= hi[i] + 1e-4, "i={i}");
        }
    });
}

#[test]
fn prop_aggregate_equal_weights_is_mean() {
    cases(100, |rng| {
        let shape = rand_shape(rng);
        let n: usize = shape.iter().product();
        let mut store = store_with("w", &shape, vec![0.0; n]);
        let names = vec!["w".to_string()];
        let mut agg = Aggregator::new(&names, &store).unwrap();
        let k = 1 + rng.below(4);
        let mut mean = vec![0.0f64; n];
        for _ in 0..k {
            let t = rand_tensor(rng, &shape);
            for i in 0..n {
                mean[i] += t[i] as f64 / k as f64;
            }
            agg.add(&[t], 1.0);
        }
        agg.finish(&mut store).unwrap();
        let out = &store.get("w").unwrap().data;
        for i in 0..n {
            assert!((out[i] as f64 - mean[i]).abs() < 1e-4);
        }
    });
}

#[test]
fn prop_sliced_full_cover_equals_plain() {
    cases(100, |rng| {
        let shape = rand_shape(rng);
        let mut s1 = store_with("w", &shape, vec![0.0; shape.iter().product()]);
        let mut s2 = s1.clone();
        let names = vec!["w".to_string()];
        let mut plain = Aggregator::new(&names, &s1).unwrap();
        let mut sliced = SlicedAggregator::new(&names, &s2).unwrap();
        for _ in 0..(1 + rng.below(4)) {
            let t = rand_tensor(rng, &shape);
            let w = rng.uniform(0.5, 3.0);
            plain.add(&[t.clone()], w);
            sliced.add(&[shape.clone()], &[t], w);
        }
        plain.finish(&mut s1).unwrap();
        sliced.finish(&mut s2).unwrap();
        for (a, b) in s1.get("w").unwrap().data.iter().zip(&s2.get("w").unwrap().data) {
            assert!((a - b).abs() < 1e-4);
        }
    });
}

#[test]
fn prop_slice_corner_roundtrip() {
    // slicing then scatter-accumulating with weight 1 reproduces the slice
    // region and leaves the rest untouched.
    cases(200, |rng| {
        let shape = rand_shape(rng);
        let full = rand_tensor(rng, &shape);
        let t = Tensor { shape: shape.clone(), data: full.clone() };
        let sub_shape: Vec<usize> = shape.iter().map(|&d| 1 + rng.below(d)).collect();
        let sub = t.slice_corner(&sub_shape).unwrap();
        assert_eq!(sub.data.len(), sub_shape.iter().product::<usize>());
        let mut acc = vec![0.0; full.len()];
        let mut wacc = vec![0.0; full.len()];
        Tensor::accumulate_corner(&shape, &mut acc, &mut wacc, &sub_shape, &sub.data, 1.0);
        for i in 0..full.len() {
            if wacc[i] > 0.0 {
                assert!((acc[i] - full[i]).abs() < 1e-6);
            } else {
                assert_eq!(acc[i], 0.0);
            }
        }
        let covered: f32 = wacc.iter().sum();
        assert_eq!(covered as usize, sub.data.len());
    });
}

// ---------------------------------------------------------------------------
// Sharded cohort merge ≡ serial (the aggregation determinism contract)
// ---------------------------------------------------------------------------

/// Multi-tensor store with rng-varied shapes for the merge properties.
fn rand_multi_store(rng: &mut Rng) -> (Vec<String>, ParamStore) {
    let mut shapes = BTreeMap::new();
    for i in 0..1 + rng.below(6) {
        shapes.insert(format!("t{i}"), rand_shape(rng));
    }
    let names: Vec<String> = shapes.keys().cloned().collect();
    let store = ParamStore::init(&shapes, rng.next_u64());
    (names, store)
}

/// Flattened f32 bit patterns of `names` in `store` (exact comparison).
fn merged_bits(store: &ParamStore, names: &[String]) -> Vec<u32> {
    names
        .iter()
        .flat_map(|n| store.get(n).unwrap().data.iter().map(|v| v.to_bits()))
        .collect()
}

#[test]
fn prop_sharded_merge_bit_identical_to_serial() {
    // The sharded-replay contract (tentpole): merging the same cohort at
    // merge threads {1, 4, 8} — through borrowed, pool-recycled owned,
    // and Arc-shared adds, with masked (projected) contributions mixed
    // in — produces bit-identical stores.
    cases(60, |rng| {
        let (names, base) = rand_multi_store(rng);
        let lens: Vec<usize> = names.iter().map(|n| base.get(n).unwrap().data.len()).collect();
        enum Add {
            Full(Vec<Vec<f32>>, f64),
            Masked(Vec<(usize, Vec<f32>)>, f64),
        }
        // A randomized add script, fixed up front so every replay sees
        // the identical op order (op order is part of the contract).
        let mut script = Vec::new();
        for _ in 0..1 + rng.below(6) {
            let tensors: Vec<Vec<f32>> =
                lens.iter().map(|&l| (0..l).map(|_| rng.normal()).collect()).collect();
            script.push(Add::Full(tensors, rng.uniform(0.1, 10.0)));
            if rng.below(3) == 0 {
                let mut parts = Vec::new();
                for (i, &l) in lens.iter().enumerate() {
                    if rng.below(2) == 0 {
                        parts.push((i, (0..l).map(|_| rng.normal()).collect::<Vec<f32>>()));
                    }
                }
                if !parts.is_empty() {
                    script.push(Add::Masked(parts, rng.uniform(0.1, 5.0)));
                }
            }
        }
        // mode 0: borrowed adds; 1: pool-recycled owned; 2: Arc-shared.
        let run = |threads: usize, mode: usize| -> Vec<u32> {
            let mut store = base.clone();
            let mut pool = TensorPool::new(4);
            let mut agg = Aggregator::new(&names, &store).unwrap();
            agg.set_merge_threads(threads);
            for add in &script {
                match add {
                    Add::Full(tensors, w) => match mode {
                        0 => agg.add(tensors, *w),
                        1 => {
                            let mut bufs = pool.acquire();
                            bufs.clear();
                            bufs.extend(tensors.iter().cloned());
                            agg.add_owned(bufs, *w);
                        }
                        _ => agg.add_shared(Arc::new(tensors.clone()), *w),
                    },
                    Add::Masked(parts, w) => agg.add_masked(parts, *w),
                }
            }
            let recycle = if mode == 1 { Some(&mut pool) } else { None };
            let stats = agg.finish_stats(&mut store, recycle).unwrap();
            assert!(stats.workers >= 1 && stats.workers <= threads.max(1), "worker count");
            let u = stats.utilization();
            assert!((0.0..=1.0).contains(&u), "utilization {u} outside [0, 1]");
            merged_bits(&store, &names)
        };
        let reference = run(1, 0);
        for threads in [1usize, 4, 8] {
            for mode in 0..3 {
                assert_eq!(run(threads, mode), reference, "threads={threads} mode={mode}");
            }
        }
    });
}

#[test]
fn prop_buffered_sharded_merge_bit_identical_to_serial() {
    // Same contract through the async buffer: staleness discounts and
    // transition-decayed projected adds do not disturb the sharded
    // replay's bit identity at any merge thread count.
    cases(60, |rng| {
        let (names, base) = rand_multi_store(rng);
        let alpha = rng.uniform(0.0, 2.0);
        let lens: Vec<usize> = names.iter().map(|n| base.get(n).unwrap().data.len()).collect();
        enum Add {
            Full(Vec<Vec<f32>>, f64, usize),
            Projected(Vec<(usize, Vec<f32>)>, f64, usize, f64),
        }
        let mut script = Vec::new();
        for _ in 0..1 + rng.below(6) {
            let tensors: Vec<Vec<f32>> =
                lens.iter().map(|&l| (0..l).map(|_| rng.normal()).collect()).collect();
            script.push(Add::Full(tensors, rng.uniform(0.1, 10.0), rng.below(6)));
            if rng.below(3) == 0 {
                let mut parts = Vec::new();
                for (i, &l) in lens.iter().enumerate() {
                    if rng.below(2) == 0 {
                        parts.push((i, (0..l).map(|_| rng.normal()).collect::<Vec<f32>>()));
                    }
                }
                if !parts.is_empty() {
                    let (w, s, d) =
                        (rng.uniform(0.1, 5.0), rng.below(6), rng.uniform(0.1, 1.0));
                    script.push(Add::Projected(parts, w, s, d));
                }
            }
        }
        let run = |threads: usize, shared: bool| -> Vec<u32> {
            let mut store = base.clone();
            let mut agg = BufferedAggregator::new(&names, &store, alpha).unwrap();
            agg.set_merge_threads(threads);
            for add in &script {
                match add {
                    Add::Full(tensors, w, s) => {
                        if shared {
                            agg.add_shared(Arc::new(tensors.clone()), *w, *s);
                        } else {
                            agg.add(tensors, *w, *s);
                        }
                    }
                    Add::Projected(parts, w, s, d) => agg.add_projected(parts, *w, *s, *d),
                }
            }
            agg.finish_stats(&mut store, None).unwrap();
            merged_bits(&store, &names)
        };
        let reference = run(1, false);
        for threads in [1usize, 4, 8] {
            for shared in [false, true] {
                assert_eq!(run(threads, shared), reference, "threads={threads} shared={shared}");
            }
        }
    });
}

#[test]
fn prop_sliced_sharded_merge_bit_identical_to_serial() {
    // The HeteroFL arena shards at whole-tensor boundaries: rng-varied
    // corner slices × weights merge to bit-identical stores at any
    // merge thread count (including counts that don't divide the
    // tensor list evenly).
    cases(60, |rng| {
        let (names, base) = rand_multi_store(rng);
        let shapes: Vec<Vec<usize>> =
            names.iter().map(|n| base.get(n).unwrap().shape.clone()).collect();
        let mut script: Vec<(Vec<Vec<usize>>, Vec<Vec<f32>>, f64)> = Vec::new();
        for _ in 0..1 + rng.below(6) {
            let mut subs = Vec::new();
            let mut tensors = Vec::new();
            for shape in &shapes {
                let sub: Vec<usize> = shape.iter().map(|&d| 1 + rng.below(d)).collect();
                let full = Tensor { shape: shape.clone(), data: rand_tensor(rng, shape) };
                tensors.push(full.slice_corner(&sub).unwrap().data);
                subs.push(sub);
            }
            script.push((subs, tensors, rng.uniform(0.1, 10.0)));
        }
        let run = |threads: usize| -> Vec<u32> {
            let mut store = base.clone();
            let mut agg = SlicedAggregator::new(&names, &store).unwrap();
            agg.set_merge_threads(threads);
            for (subs, tensors, w) in &script {
                agg.add_owned(subs.clone(), tensors.clone(), *w);
            }
            agg.finish_stats(&mut store).unwrap();
            merged_bits(&store, &names)
        };
        let reference = run(1);
        for threads in [3usize, 4, 8] {
            assert_eq!(run(threads), reference, "threads={threads}");
        }
    });
}

// ---------------------------------------------------------------------------
// Fleet-simulator churn invariants
// ---------------------------------------------------------------------------

fn rand_trace(rng: &mut Rng) -> AvailabilityTrace {
    if rng.f64() < 0.3 {
        AvailabilityTrace::always_on()
    } else {
        let period = rng.uniform(20.0, 200.0);
        let duty = rng.uniform(0.2, 1.0);
        let phase = rng.uniform(0.0, period);
        AvailabilityTrace { period_s: period, duty, phase_s: phase }
    }
}

fn rand_works(rng: &mut Rng, with_dropout: bool) -> Vec<ClientWork> {
    let n = 2 + rng.below(8);
    (0..n)
        .map(|id| {
            let trace = rand_trace(rng);
            ClientWork {
                id,
                ready_s: trace.next_online(0.0),
                down_s: rng.uniform(0.1, 10.0),
                train_s: rng.uniform(1.0, 300.0),
                up_s: rng.uniform(0.1, 20.0),
                dropout_p: if with_dropout && rng.f64() < 0.3 {
                    rng.uniform(0.0, 1.0)
                } else {
                    0.0
                },
                trace,
            }
        })
        .collect()
}

fn rand_policy(rng: &mut Rng) -> (RoundPolicy, usize) {
    match rng.below(4) {
        0 => (RoundPolicy::Sync, usize::MAX),
        1 => (RoundPolicy::Deadline { secs: rng.uniform(10.0, 400.0) }, usize::MAX),
        2 => (RoundPolicy::OverSelect { extra: 2 }, 1 + rng.below(4)),
        _ => (RoundPolicy::Async { buffer_k: 1 + rng.below(5), max_staleness: 8 }, usize::MAX),
    }
}

fn rand_churn(rng: &mut Rng) -> ChurnPolicy {
    match rng.below(4) {
        0 => ChurnPolicy::None,
        1 => ChurnPolicy::Abort,
        2 => ChurnPolicy::Resume,
        _ => ChurnPolicy::Checkpoint { epochs: 1 + rng.below(8) },
    }
}

#[test]
fn prop_churn_clock_monotone_and_finite() {
    // Interrupt/Resume events slot into the queue like any other: the
    // processed-event stream stays time-ordered and finite under every
    // policy × churn combination.
    cases(200, |rng| {
        let works = rand_works(rng, true);
        let (policy, keep) = rand_policy(rng);
        let churn = rand_churn(rng);
        let mut engine = FleetEngine::new();
        let plan = engine.simulate_round(0, 0.0, &works, policy, keep, churn, rng);
        assert!(plan.end_s.is_finite() && plan.end_s >= plan.start_s);
        for pair in plan.events.windows(2) {
            assert!(pair[0].time_s.is_finite());
            assert!(
                pair[0].time_s <= pair[1].time_s,
                "clock went backwards: {} -> {} ({policy:?} × {churn:?})",
                pair[0].time_s,
                pair[1].time_s
            );
        }
    });
}

#[test]
fn prop_wasted_compute_nonnegative_and_zero_without_loss() {
    // wasted_compute_s is a loss meter: never negative, never NaN, and
    // identically zero under churn policies that lose no work.
    cases(200, |rng| {
        let works = rand_works(rng, true);
        let (policy, keep) = rand_policy(rng);
        let churn = rand_churn(rng);
        let mut engine = FleetEngine::new();
        let plan = engine.simulate_round(0, 0.0, &works, policy, keep, churn, rng);
        assert!(plan.wasted_compute_s.is_finite());
        assert!(plan.wasted_compute_s >= 0.0, "{policy:?} × {churn:?}");
        if matches!(churn, ChurnPolicy::None | ChurnPolicy::Resume) {
            assert_eq!(plan.wasted_compute_s, 0.0, "lossless churn wasted compute");
            assert!(plan.aborted.is_empty());
        }
        if !matches!(churn, ChurnPolicy::Checkpoint { .. }) {
            assert!(plan.partials.is_empty(), "only checkpoint produces partials");
        }
    });
}

#[test]
fn prop_partial_update_weight_below_full() {
    // A checkpointed fraction is epoch-truncated strictly below 1 (and
    // above 0), so a partial update's merge weight is always less than
    // the client's full-shard weight.
    cases(200, |rng| {
        let works = rand_works(rng, false);
        let (policy, keep) = rand_policy(rng);
        let epochs = 1 + rng.below(8);
        let churn = ChurnPolicy::Checkpoint { epochs };
        let mut engine = FleetEngine::new();
        let plan = engine.simulate_round(0, 0.0, &works, policy, keep, churn, rng);
        for &(c, f) in &plan.partials {
            assert!(f > 0.0 && f < 1.0, "client {c}: fraction {f} out of (0,1)");
            let scaled = (f * epochs as f64).round();
            assert!((scaled - f * epochs as f64).abs() < 1e-9, "not epoch-granular: {f}");
        }
    });
}

#[test]
fn prop_resume_never_finishes_earlier_than_uninterrupted() {
    // Pausing across offline windows can only delay an upload relative
    // to the churn-free schedule (same works, same sync policy).
    cases(200, |rng| {
        let works = rand_works(rng, false);
        let upload_times = |churn: ChurnPolicy| -> BTreeMap<usize, f64> {
            let plan =
                simulate_round(0.0, &works, RoundPolicy::Sync, usize::MAX, churn, &mut Rng::new(1));
            plan.events
                .iter()
                .filter_map(|e| match e.kind {
                    EventKind::UploadDone { client } => Some((client, e.time_s)),
                    _ => None,
                })
                .collect()
        };
        let base = upload_times(ChurnPolicy::None);
        let resumed = upload_times(ChurnPolicy::Resume);
        assert_eq!(base.len(), resumed.len(), "resume loses nobody under sync");
        for (c, t) in &resumed {
            assert!(
                *t >= base[c] - 1e-9,
                "client {c} finished early: resume {} < uninterrupted {}",
                t,
                base[c]
            );
        }
    });
}

#[test]
fn prop_churn_buckets_conserve_the_cohort() {
    // Conservation across multiple async rounds: every dispatched client
    // is merged, partial-merged, dropped, aborted, straggled, or still
    // in flight — exactly one of them, every round.
    cases(150, |rng| {
        let (policy, keep) = rand_policy(rng);
        let churn = rand_churn(rng);
        let mut engine = FleetEngine::new();
        let mut start = 0.0;
        for round in 0..3 {
            // Fresh ids per round so in-flight uploads are never
            // superseded (the coordinator's sampling guarantees this).
            let mut works = rand_works(rng, true);
            for w in &mut works {
                w.id += round * 100;
            }
            let inflight_before: Vec<usize> =
                engine.inflight().iter().map(|u| u.client).collect();
            let plan = engine.simulate_round(round, start, &works, policy, keep, churn, rng);
            let mut seen = std::collections::BTreeSet::new();
            for bucket in
                [&plan.completers, &plan.stragglers, &plan.dropouts, &plan.aborted, &plan.deferred]
            {
                for &id in bucket.iter() {
                    assert!(seen.insert(id), "client {id} in two buckets ({policy:?}×{churn:?})");
                }
            }
            assert_eq!(seen.len(), works.len(), "client unaccounted ({policy:?}×{churn:?})");
            // In-flight uploads either landed this round or are still
            // queued — none vanish.
            let landed: Vec<usize> = plan.late_arrivals.iter().map(|u| u.client).collect();
            let still: Vec<usize> = engine.inflight().iter().map(|u| u.client).collect();
            for c in inflight_before {
                assert!(
                    landed.contains(&c) || still.contains(&c),
                    "in-flight upload of {c} vanished"
                );
            }
            start = plan.end_s;
        }
    });
}

#[test]
fn prop_download_fractions_bounded_and_charged_once() {
    // Partial-download accounting (ROADMAP churn follow-on): every
    // churn-aborted client records exactly one completed-download
    // fraction in [0, 1] — so charging `fraction × bytes` can never
    // exceed the full download — and lossless policies record none.
    // Under `resume`, paused downloads complete exactly once: each
    // client emits at most one TrainDone and one UploadDone, so the
    // ordinary charge sites fire at most once per download.
    cases(200, |rng| {
        let works = rand_works(rng, true);
        let (policy, keep) = rand_policy(rng);
        let churn = rand_churn(rng);
        let mut engine = FleetEngine::new();
        let plan = engine.simulate_round(0, 0.0, &works, policy, keep, churn, rng);
        assert_eq!(plan.download_frac.len(), plan.aborted.len(), "one fraction per abort");
        for &(c, f) in &plan.download_frac {
            assert!(plan.aborted.contains(&c), "fraction for a non-aborted client");
            assert!((0.0..=1.0).contains(&f), "fraction {f} outside [0, 1]");
            let bytes = 44_000_000u64;
            assert!((f * bytes as f64) as u64 <= bytes, "partial charge exceeds full");
        }
        let unique: std::collections::BTreeSet<usize> =
            plan.download_frac.iter().map(|(c, _)| *c).collect();
        assert_eq!(unique.len(), plan.download_frac.len(), "a download charged twice");
        if matches!(churn, ChurnPolicy::None | ChurnPolicy::Resume) {
            assert!(plan.download_frac.is_empty(), "lossless churn aborts nothing");
        }
        if matches!(churn, ChurnPolicy::Resume) {
            let mut train_done: BTreeMap<usize, usize> = BTreeMap::new();
            let mut upload_done: BTreeMap<usize, usize> = BTreeMap::new();
            for e in &plan.events {
                match e.kind {
                    EventKind::TrainDone { client } => *train_done.entry(client).or_insert(0) += 1,
                    EventKind::UploadDone { client } => {
                        *upload_done.entry(client).or_insert(0) += 1
                    }
                    _ => {}
                }
            }
            for (&c, &n) in train_done.iter().chain(upload_done.iter()) {
                assert!(n <= 1, "client {c} finished a span {n} times under resume");
            }
        }
    });
}

#[test]
fn prop_parallel_plan_equals_sequential_sorted_order() {
    // The deterministic-merge contract: the worker-pool span planner,
    // merged through the event queue's (time, seq) order, reproduces the
    // sequential plan exactly — same events (virtual times to the bit,
    // seqs, kinds), same buckets — under rng-varied schedules, policies,
    // churn, and dropout, across rounds with async in-flight state
    // crossing them.
    cases(120, |rng| {
        let (policy, keep) = rand_policy(rng);
        let churn = rand_churn(rng);
        let threads = 2 + rng.below(7);
        let seed = rng.next_u64();
        let mut seq_engine = FleetEngine::with_threads(1);
        let mut par_engine = FleetEngine::with_threads(threads);
        let mut seq_rng = Rng::new(seed);
        let mut par_rng = Rng::new(seed);
        let mut start = 0.0;
        for round in 0..3 {
            // Fresh ids per round so in-flight uploads are never
            // superseded (the coordinator's sampling guarantees this).
            let mut works = rand_works(rng, true);
            for w in &mut works {
                w.id += round * 100;
            }
            let a = seq_engine
                .simulate_round(round, start, &works, policy, keep, churn, &mut seq_rng);
            let b = par_engine
                .simulate_round(round, start, &works, policy, keep, churn, &mut par_rng);
            assert_eq!(
                a, b,
                "{policy:?}×{churn:?} diverged at {threads} threads, round {round}"
            );
            assert_eq!(a.end_s.to_bits(), b.end_s.to_bits(), "round end drifted");
            // The merged stream really is (time, seq)-sorted.
            for pair in b.events.windows(2) {
                let (t0, s0) = (pair[0].time_s, pair[0].seq);
                let (t1, s1) = (pair[1].time_s, pair[1].seq);
                assert!(
                    t0 < t1 || (t0 == t1 && s0 < s1),
                    "merge order violated (time, seq): ({t0}, {s0}) -> ({t1}, {s1})"
                );
            }
            start = a.end_s;
        }
    });
}

// ---------------------------------------------------------------------------
// Stale-update projection invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_projection_conserves_scalars_and_masks_frozen() {
    // Over random layout pairs drawn from a shared name pool: every
    // scalar of the stale update is either kept (remapped onto a
    // still-trainable tensor of identical length) or counted dropped —
    // nothing is lost or invented — and no kept tensor lands on a name
    // absent from the update or the new layout (frozen blocks never
    // receive mass).
    cases(200, |rng| {
        let n_pool = 8usize;
        let base: Vec<usize> = (0..n_pool).map(|_| 1 + rng.below(5)).collect();
        let mut old = TrainableLayout::default();
        let mut new = TrainableLayout::default();
        for (i, len) in base.iter().enumerate() {
            let name = format!("p{i}");
            if rng.f64() < 0.6 {
                old.names.push(name.clone());
                old.lens.push(*len);
            }
            if rng.f64() < 0.6 {
                // Occasionally reshape a tensor in the new layout: same
                // name, different length — must be dropped, not merged.
                let l = if rng.f64() < 0.1 { *len + 1 } else { *len };
                new.names.push(name);
                new.lens.push(l);
            }
        }
        let tensors: Vec<Vec<f32>> = old.lens.iter().map(|&l| vec![1.0; l]).collect();
        let total: usize = old.lens.iter().sum();
        let (kept, dropped) = project_tensors(&old, &new, tensors);
        let kept_scalars: usize = kept.iter().map(|(_, t)| t.len()).sum();
        assert_eq!(kept_scalars as u64 + dropped, total as u64, "scalars not conserved");
        let mut seen = std::collections::BTreeSet::new();
        for (idx, t) in &kept {
            assert!(seen.insert(*idx), "tensor merged twice at index {idx}");
            assert_eq!(new.lens[*idx], t.len(), "length mismatch survived projection");
            let name = &new.names[*idx];
            assert!(old.names.contains(name), "kept tensor not from the update");
        }
        // Weight side of the contract: the projected merge factor never
        // exceeds the original weight's, and decays monotonically in
        // transitions crossed.
        let alpha = rng.uniform(0.0, 2.0);
        let decay = rng.uniform(0.0, 1.0);
        let staleness = rng.below(6);
        let mut prev = f64::INFINITY;
        for transitions in 0..5u64 {
            let f = staleness_discount(staleness, alpha) * transition_decay(decay, transitions);
            assert!(f <= 1.0 + 1e-12, "projected weight amplified");
            assert!(f <= prev + 1e-12, "decay not monotone in transitions");
            prev = f;
        }
    });
}

// ---------------------------------------------------------------------------
// Effective movement invariants (§3.3)
// ---------------------------------------------------------------------------

#[test]
fn prop_effective_movement_bounded() {
    cases(100, |rng| {
        let n = 1 + rng.below(200);
        let h = 1 + rng.below(5);
        let mut em = EffectiveMovement::new(h);
        let mut v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        for _ in 0..(h + 3 + rng.below(5)) {
            for x in v.iter_mut() {
                *x += rng.normal() * 0.1;
            }
            if let Some(e) = em.push(&v) {
                assert!((0.0..=1.0 + 1e-9).contains(&e), "em={e}");
            }
        }
    });
}

#[test]
fn prop_effective_movement_one_for_monotone() {
    // Any per-scalar *consistent-sign* motion gives EM == 1 regardless of
    // magnitudes (the numerator equals the denominator scalar-wise).
    cases(100, |rng| {
        let n = 1 + rng.below(100);
        let h = 1 + rng.below(4);
        let signs: Vec<f32> = (0..n).map(|_| if rng.f64() < 0.5 { -1.0 } else { 1.0 }).collect();
        let mut em = EffectiveMovement::new(h);
        let mut v = vec![0.0f32; n];
        let mut last = None;
        for _ in 0..(h + 2) {
            for (x, s) in v.iter_mut().zip(&signs) {
                *x += s * (0.01 + rng.f32().abs());
            }
            last = em.push(&v).or(last);
        }
        let e = last.unwrap();
        assert!((e - 1.0).abs() < 1e-6, "em={e}");
    });
}

#[test]
fn prop_ls_slope_exact_on_lines() {
    cases(200, |rng| {
        let n = 2 + rng.below(20);
        let a = rng.normal() as f64 * 3.0;
        let b = rng.normal() as f64;
        let ys: Vec<f64> = (0..n).map(|i| a * i as f64 + b).collect();
        assert!((ls_slope(&ys) - a).abs() < 1e-6 * (1.0 + a.abs()));
    });
}

// ---------------------------------------------------------------------------
// Data partition invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_partition_indices_unique_and_labels_valid() {
    cases(30, |rng| {
        let classes = 2 + rng.below(20);
        let data = SyntheticDataset::new(classes, rng.next_u64());
        let clients = 2 + rng.below(30);
        let scheme = if rng.f64() < 0.5 {
            Partition::Iid
        } else {
            Partition::Dirichlet { alpha: rng.uniform(0.05, 10.0) }
        };
        let shards = partition(&data, clients, 50 * clients, scheme, rng.next_u64());
        assert_eq!(shards.len(), clients);
        let mut seen = std::collections::HashSet::new();
        for s in &shards {
            assert!(s.num_samples() >= 8);
            for &l in &s.labels {
                assert!((l as usize) < classes);
            }
            for &i in &s.indices {
                assert!(seen.insert(i), "duplicate index {i}");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// ParamStore init invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_store_init_finite_and_rule_based() {
    cases(50, |rng| {
        let mut shapes = BTreeMap::new();
        for i in 0..(1 + rng.below(6)) {
            let kind = rng.below(3);
            let name = match kind {
                0 => format!("b1/l{i}/w"),
                1 => format!("b1/l{i}/scale"),
                _ => format!("b1/l{i}/shift"),
            };
            shapes.insert(name, rand_shape(rng));
        }
        let store = ParamStore::init(&shapes, rng.next_u64());
        for name in shapes.keys() {
            let t = store.get(name).unwrap();
            for &v in &t.data {
                assert!(v.is_finite());
                if name.ends_with("/scale") {
                    assert_eq!(v, 1.0);
                }
                if name.ends_with("/shift") {
                    assert_eq!(v, 0.0);
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// JSON parser invariants
// ---------------------------------------------------------------------------

fn rand_json(rng: &mut Rng, depth: usize) -> Value {
    if depth == 0 {
        return match rng.below(4) {
            0 => Value::Null,
            1 => Value::Bool(rng.f64() < 0.5),
            2 => Value::Num((rng.normal() as f64 * 100.0).round()),
            _ => Value::Str(format!("s{}", rng.below(1000))),
        };
    }
    match rng.below(2) {
        0 => Value::Arr((0..rng.below(4)).map(|_| rand_json(rng, depth - 1)).collect()),
        _ => Value::Obj(
            (0..rng.below(4)).map(|i| (format!("k{i}"), rand_json(rng, depth - 1))).collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    cases(300, |rng| {
        let v = rand_json(rng, 3);
        let text = v.to_json();
        let v2 = Value::parse(&text).unwrap();
        assert_eq!(v, v2, "text: {text}");
    });
}

// ---------------------------------------------------------------------------
// RNG invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_dirichlet_valid_simplex() {
    cases(100, |rng| {
        let k = 2 + rng.below(50);
        let alpha = rng.uniform(0.01, 20.0);
        let p = rng.dirichlet(alpha, k);
        assert_eq!(p.len(), k);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x > 0.0));
    });
}

#[test]
fn prop_sample_indices_is_permutation_prefix() {
    cases(100, |rng| {
        let n = 1 + rng.below(100);
        let k = rng.below(n + 1);
        let s = rng.sample_indices(n, k);
        assert_eq!(s.len(), k);
        let mut u: Vec<_> = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), k);
        assert!(s.iter().all(|&i| i < n));
    });
}

// ---------------------------------------------------------------------------
// Lazy client pool ≡ eager build (the O(cohort) round-scheduling contract)
// ---------------------------------------------------------------------------

fn rand_scheme(rng: &mut Rng) -> Partition {
    if rng.below(2) == 0 {
        Partition::Iid
    } else {
        Partition::Dirichlet { alpha: rng.uniform(0.2, 3.0) }
    }
}

fn pool_pair(rng: &mut Rng) -> (ClientPool, ClientPool, usize) {
    let seed = rng.next_u64();
    let n = 10 + rng.below(110);
    let scheme = rand_scheme(rng);
    let profile_name = ["uniform", "mobile", "datacenter"][rng.below(3)];
    let cap = 4 + rng.below(40);
    let data = SyntheticDataset::new(10, seed);
    let fleet = profl::fleet::FleetProfileConfig::named(profile_name).unwrap();
    let eager = ClientPool::build(
        n,
        n * 60,
        &data,
        scheme,
        MemoryConfig::default(),
        &fleet,
        seed,
    );
    let lazy = ClientPool::build_lazy(
        n,
        n * 60,
        &data,
        scheme,
        MemoryConfig::default(),
        &fleet,
        seed,
        cap,
    );
    (eager, lazy, n)
}

#[test]
fn prop_lazy_materialization_bit_identical_to_eager() {
    // Satellite acceptance: same seeds ⇒ same memory budgets, device
    // profiles, shard bounds (labels, indices, counts) — for random
    // fleet sizes, partition schemes, profiles, and resident caps, with
    // clients materialized in random order.
    cases(25, |rng| {
        let (eager, mut lazy, n) = pool_pair(rng);
        assert_eq!(eager.len(), lazy.len());
        assert_eq!(eager.total_samples(), lazy.total_samples());
        for _ in 0..20 {
            let id = rng.below(n);
            let l = lazy.client_mut(id);
            assert_eq!(l.id, id);
            let e = eager.client(id);
            let l = lazy.client(id);
            assert_eq!(e.memory.budget, l.memory.budget, "client {id} budget");
            assert_eq!(e.profile, l.profile, "client {id} profile");
            assert_eq!(e.shard.num_samples(), l.shard.num_samples(), "client {id} bound");
            assert_eq!(e.shard.labels, l.shard.labels, "client {id} labels");
            assert_eq!(e.shard.indices, l.shard.indices, "client {id} indices");
        }
        // Fleet-wide pure aggregates agree without materialization.
        let probe = MemCoeffs {
            fixed_bytes: 400 * 1_000_000,
            per_sample_bytes: 0,
            params_total: 0,
            params_trainable: 0,
        };
        assert_eq!(eager.participation_rate(&probe), lazy.participation_rate(&probe));
        assert_eq!(
            eager.capability_assignment(&[probe]),
            lazy.capability_assignment(&[probe])
        );
    });
}

#[test]
fn prop_lazy_selection_streams_match_eager_across_rounds() {
    // Satellite acceptance: the selection rng stream (positions AND
    // outputs) is identical across storage modes over many rounds, with
    // random in-flight exclusion sets — including the empty set, which
    // must consume the stream exactly like plain select.
    cases(15, |rng| {
        let (mut eager, mut lazy, n) = pool_pair(rng);
        let probe = MemCoeffs {
            fixed_bytes: 350 * 1_000_000,
            per_sample_bytes: 0,
            params_total: 0,
            params_trainable: 0,
        };
        for round in 0..8 {
            let busy: Vec<usize> = if rng.below(3) == 0 {
                Vec::new()
            } else {
                (0..rng.below(n / 2 + 1)).map(|_| rng.below(n)).collect()
            };
            let k = 1 + rng.below(n.min(30));
            let a = eager.select_excluding(k, &probe, &busy);
            let b = lazy.select_excluding(k, &probe, &busy);
            assert_eq!(a.trainers, b.trainers, "round {round} busy={busy:?}");
            assert_eq!(a.fallback, b.fallback, "round {round}");
            assert_eq!(a.availability, b.availability, "round {round}");
            for (id, _) in &a.availability {
                assert!(!busy.contains(id), "busy client {id} sampled");
            }
        }
    });
}

#[test]
fn prop_select_excluding_empty_consumes_identical_stream() {
    // Regression (satellite): select_excluding(∅) must stay draw-for-draw
    // identical to select — interleaving the two spellings across rounds
    // on same-seed pools cannot make them diverge.
    cases(15, |rng| {
        let (mut a, mut b, n) = pool_pair(rng);
        let probe = MemCoeffs {
            fixed_bytes: 300 * 1_000_000,
            per_sample_bytes: 0,
            params_total: 0,
            params_trainable: 0,
        };
        for _ in 0..6 {
            let k = 1 + rng.below(n.min(25));
            let s1 = a.select(k, &probe);
            let s2 = b.select_excluding(k, &probe, &[]);
            assert_eq!(s1.availability, s2.availability);
        }
    });
}

#[test]
fn prop_sparse_sampling_equals_dense_fisher_yates() {
    // sample_indices must reproduce the dense partial Fisher-Yates bit
    // for bit (outputs and draw count) whatever (n, k) — the sparse path
    // is an invisible optimization.
    cases(200, |rng| {
        let n = 1 + rng.below(3_000);
        let k = rng.below(n + 1);
        let mut a = Rng::new(rng.next_u64());
        let mut b = a.clone();
        let sparse = a.sample_indices(n, k);
        let dense = {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + b.below(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        };
        assert_eq!(sparse, dense, "n={n} k={k}");
        assert_eq!(a.next_u64(), b.next_u64(), "stream positions diverged");
    });
}

#[test]
fn prop_lazy_peak_materialized_bounded_by_cap() {
    // The memory wall: whatever the access pattern, a lazy pool never
    // holds more than its resident cap.
    cases(20, |rng| {
        let (_, mut lazy, n) = pool_pair(rng);
        let cap_probe = MemCoeffs {
            fixed_bytes: 0,
            per_sample_bytes: 0,
            params_total: 0,
            params_trainable: 0,
        };
        for _ in 0..10 {
            let k = 1 + rng.below(n.min(20));
            let _ = lazy.select(k, &cap_probe);
        }
        assert!(lazy.peak_materialized() <= n, "peak can never exceed the fleet");
        assert!(lazy.materialized() <= lazy.peak_materialized());
    });
}

// ---------------------------------------------------------------------------
// Memory-strategy invariants (strategy::, docs/STRATEGIES.md)
// ---------------------------------------------------------------------------

fn rand_counts(rng: &mut Rng) -> Vec<u64> {
    let n = 2 + rng.below(8);
    (0..n).map(|_| 100_000 + rng.below(5_000_000) as u64).collect()
}

#[test]
fn prop_footprint_monotone_in_trainable_prefix() {
    // Deepening the trainable window over a fixed frozen floor never
    // shrinks the analytical footprint, at any accounting batch.
    cases(200, |rng| {
        let counts = rand_counts(rng);
        let frozen = rng.below(counts.len());
        let batch = 1 + rng.below(256) as u64;
        let mut prev = 0u64;
        for depth in frozen + 1..=counts.len() {
            let m = layout_mem(&counts, &BlockLayout { frozen, depth });
            let b = m.bytes_at(batch);
            assert!(b >= prev, "footprint shrank at depth {depth}");
            assert!(m.params_trainable <= m.params_total);
            prev = b;
        }
    });
}

#[test]
fn prop_footprint_never_exceeds_full_model() {
    // No partial layout costs more than training the whole model: the
    // bound the strategy zoo's peak-memory column leans on.
    cases(200, |rng| {
        let counts = rand_counts(rng);
        let batch = 1 + rng.below(256) as u64;
        let full = layout_mem(&counts, &BlockLayout::full(counts.len())).bytes_at(batch);
        let frozen = rng.below(counts.len());
        let depth = frozen + 1 + rng.below(counts.len() - frozen);
        let m = layout_mem(&counts, &BlockLayout { frozen, depth });
        assert!(
            m.bytes_at(batch) <= full,
            "partial layout ({frozen}, {depth}) out-costs the full model"
        );
    });
}

#[test]
fn prop_layerfreeze_depth_caps_respect_fits_static() {
    // The per-client depth cap is sound and maximal: the capped layout
    // always fits the device's static budget, one block deeper never
    // does, and a None cap means even a single block does not fit. Any
    // client the contended can_train filter then admits for the capped
    // layout fits it statically (dispatch respects fits_static).
    cases(100, |rng| {
        let counts = rand_counts(rng);
        let mcfg = MemoryConfig::default();
        let mut pool_rng = Rng::new(rng.next_u64());
        let frozen = rng.below(counts.len());
        for i in 0..40 {
            let mut d = DeviceMemory::sample(&mcfg, &mut pool_rng, i);
            match depth_cap(&counts, frozen, d.budget, mcfg.accounting_batch) {
                Some(layout) => {
                    assert_eq!(layout.frozen, frozen);
                    assert!(layout.depth > frozen && layout.depth <= counts.len());
                    let m = layout_mem(&counts, &layout);
                    assert!(d.fits_static(&mcfg, &m), "capped layout overflows budget");
                    if layout.depth < counts.len() {
                        let deeper =
                            layout_mem(&counts, &BlockLayout { frozen, depth: layout.depth + 1 });
                        assert!(!d.fits_static(&mcfg, &deeper), "cap is not maximal");
                    }
                    let avail = d.available(&mcfg);
                    if can_train(avail, &mcfg, &m) {
                        assert!(d.fits_static(&mcfg, &m), "dispatched client overflows");
                    }
                }
                None => {
                    let min = layout_mem(&counts, &BlockLayout { frozen, depth: frozen + 1 });
                    assert!(!d.fits_static(&mcfg, &min), "a fit exists but the cap is None");
                }
            }
        }
    });
}

#[test]
fn prop_elastic_windows_fit_budgets_and_dispatch_respects_fits_static() {
    // Every planned elastic window fits its own budget-curve point (or
    // is the guaranteed single-block floor), windows tile the depth
    // without gaps, and every device the can_train filter admits for a
    // phase's footprint also fits it statically.
    cases(100, |rng| {
        let counts = rand_counts(rng);
        let mut cfg = RunConfig::smoke("m");
        cfg.memory.budget_min_mb = 50 + rng.below(300) as u64;
        cfg.memory.budget_max_mb = cfg.memory.budget_min_mb + 50 + rng.below(800) as u64;
        cfg.strategy.elastic_phases = Some(1 + rng.below(6));
        let phases = elastic::plan(&counts, &cfg);
        assert!(!phases.is_empty());
        let mut expect_frozen = 0;
        for ph in &phases {
            assert_eq!(ph.layout.frozen, expect_frozen, "windows must tile");
            assert!(ph.layout.depth > ph.layout.frozen);
            assert!(ph.rounds >= 1);
            let m = layout_mem(&counts, &ph.layout);
            let fits = m.bytes_at(cfg.memory.accounting_batch) <= ph.budget_bytes;
            let floor = ph.layout.depth == ph.layout.frozen + 1;
            assert!(fits || floor, "window neither fits its budget nor is the floor");
            expect_frozen = ph.layout.depth;
        }
        assert!(phases.last().unwrap().layout.depth <= counts.len());
        let mcfg: MemoryConfig = cfg.memory.into();
        let mut pool_rng = Rng::new(rng.next_u64());
        for i in 0..30 {
            let mut d = DeviceMemory::sample(&mcfg, &mut pool_rng, i);
            let avail = d.available(&mcfg);
            for ph in &phases {
                let m = layout_mem(&counts, &ph.layout);
                if can_train(avail, &mcfg, &m) {
                    assert!(d.fits_static(&mcfg, &m), "dispatched client overflows");
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Checkpoint/resume (checkpoint::, docs/CHECKPOINT.md)
// ---------------------------------------------------------------------------

/// Floats with teeth: specials show up often enough to catch any codec
/// path that formats instead of preserving bit patterns.
fn rand_f32x(rng: &mut Rng) -> f32 {
    match rng.below(8) {
        0 => f32::NAN,
        1 => f32::INFINITY,
        2 => f32::NEG_INFINITY,
        3 => -0.0,
        _ => rng.normal(),
    }
}

fn rand_f64x(rng: &mut Rng) -> f64 {
    match rng.below(8) {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => -0.0,
        _ => rng.uniform(-1e9, 1e9),
    }
}

/// Strings with quotes, escapes, spaces, and multi-byte code points.
fn rand_name(rng: &mut Rng) -> String {
    let set = ["a", "Z", "0", "_", "/", "é", "💾", "\"", "\\", " ", "\n"];
    (0..rng.below(12)).map(|_| set[rng.below(set.len())]).collect()
}

fn rand_record(rng: &mut Rng) -> RoundRecord {
    RoundRecord {
        round: rng.below(1000),
        stage: rand_name(rng),
        step: rng.below(8),
        train_loss: rand_f32x(rng),
        train_acc: rand_f32x(rng),
        test_acc: rand_f32x(rng),
        effective_movement: rand_f64x(rng),
        participants: rng.below(100),
        fallback_participants: rng.below(100),
        bytes_up: rng.next_u64() >> rng.below(40),
        bytes_down: rng.next_u64() >> rng.below(40),
        client_mem_bytes: rng.next_u64() >> rng.below(40),
        sim_time_s: rand_f64x(rng),
        stragglers: rng.below(20),
        dropouts: rng.below(20),
        late_merged: rng.below(20),
        late_dropped: rng.below(20),
        mean_staleness: rand_f64x(rng),
        projected_merged: rng.below(20),
        projected_dropped_params: rng.next_u64() >> rng.below(40),
        transition_staleness: rand_f64x(rng),
        interrupted: rng.below(20),
        resumed: rng.below(20),
        partial_merged: rng.below(20),
        wasted_compute_s: rand_f64x(rng),
    }
}

fn rand_client_ckpt(rng: &mut Rng, id: usize) -> ClientCkpt {
    ClientCkpt {
        id,
        mem_rng: rng.next_u64(),
        cursor: rng.below(5000),
        prefix_version: rng.next_u64() >> 32,
    }
}

fn rand_pool_state(rng: &mut Rng) -> PoolCkptState {
    let kind = if rng.below(2) == 0 {
        PoolCkptKind::Eager((0..rng.below(8)).map(|id| rand_client_ckpt(rng, id)).collect())
    } else {
        PoolCkptKind::Lazy(LazyCkpt {
            tick: rng.next_u64() >> 16,
            peak_resident: rng.below(64),
            hits: rng.next_u64() >> 32,
            misses: rng.next_u64() >> 32,
            evictions: rng.next_u64() >> 32,
            resident: (0..rng.below(6))
                .map(|id| (rand_client_ckpt(rng, id), rng.next_u64() >> 16))
                .collect(),
            evicted: (10..10 + rng.below(6)).map(|id| rand_client_ckpt(rng, id)).collect(),
        })
    };
    PoolCkptState { select_rng: rng.next_u64(), kind }
}

fn rand_train_phase(rng: &mut Rng) -> TrainPhase {
    TrainPhase {
        stage: rand_name(rng),
        step: 1 + rng.below(6),
        layout: BlockLayout { frozen: rng.below(3), depth: 1 + rng.below(4) },
        train_artifact: rand_name(rng),
        fallback_artifact: if rng.below(2) == 0 { None } else { Some(rand_name(rng)) },
        eval_artifact: rand_name(rng),
        observe_params: (0..rng.below(5)).map(|_| rand_name(rng)).collect(),
        lr: rand_f32x(rng),
        max_rounds: 1 + rng.below(30),
        min_rounds: 1 + rng.below(5),
        em_gated: rng.below(2) == 0,
    }
}

fn rand_mid(rng: &mut Rng) -> Option<MidPhase> {
    match rng.below(3) {
        0 => None,
        1 => Some(MidPhase::Train {
            phase: rand_train_phase(rng),
            detector: DetectorSnapshot {
                deltas: (0..rng.below(4))
                    .map(|_| (0..rng.below(6)).map(|_| rand_f32x(rng)).collect())
                    .collect(),
                prev: if rng.below(2) == 0 {
                    None
                } else {
                    Some((0..rng.below(6)).map(|_| rand_f32x(rng)).collect())
                },
                history: (0..rng.below(6)).map(|_| rand_f64x(rng)).collect(),
                consecutive: rng.below(4),
            },
            used: rng.below(20),
            froze: rng.below(2) == 0,
        }),
        _ => Some(MidPhase::Distill {
            phase: DistillPhase {
                stage: rand_name(rng),
                step: rng.below(6),
                artifact: rand_name(rng),
                rounds: 1 + rng.below(10),
                lr: rand_f32x(rng),
            },
            used: rng.below(10),
        }),
    }
}

/// A structurally valid but otherwise adversarially-random checkpoint:
/// every field exercises the codec, including float specials and hostile
/// strings. Transitions stay monotone and pending stays id-sorted — the
/// two structural invariants the decoder enforces.
fn rand_checkpoint(rng: &mut Rng) -> Checkpoint {
    let mut transitions = Vec::new();
    let (mut ver, mut round, mut t) = (0u64, 0usize, 0.0f64);
    for _ in 0..rng.below(5) {
        ver += 1 + rng.below(3) as u64;
        round += rng.below(4);
        t += rng.uniform(0.0, 50.0);
        transitions.push(Transition { version: ver, round, sim_time_s: t });
    }
    let mut pending = Vec::new();
    let mut client = 0usize;
    for _ in 0..rng.below(4) {
        client += 1 + rng.below(5);
        pending.push(PendingUpdate {
            client,
            artifact: rand_name(rng),
            prefix_version: rng.next_u64() >> 32,
            dispatch_round: rng.below(100),
            weight: rand_f64x(rng),
            partial: rng.below(2) == 0,
            bytes_up: rng.next_u64() >> rng.below(40),
            tensors: (0..rng.below(3))
                .map(|_| (0..rng.below(20)).map(|_| rand_f32x(rng)).collect())
                .collect(),
        });
    }
    let params: Vec<(String, Vec<usize>, Vec<f32>)> = (0..rng.below(5))
        .map(|i| {
            let shape = rand_shape(rng);
            let data = rand_tensor(rng, &shape);
            (format!("p{i:03}/{}", rand_name(rng).replace('\n', "n")), shape, data)
        })
        .collect();
    Checkpoint {
        crate_version: env!("CARGO_PKG_VERSION").to_string(),
        config_sha256: rand_name(rng),
        config_json: rand_name(rng),
        round: rng.below(500),
        sim_time_s: rand_f64x(rng),
        prefix_version: rng.next_u64() >> 32,
        transitions,
        fleet_rng: rng.next_u64(),
        threads: 1 + rng.below(8),
        inflight: (0..rng.below(5))
            .map(|_| profl::fleet::InFlightUpload {
                client: rng.below(100),
                arrive_s: rng.uniform(0.0, 1e6),
                dispatch_round: rng.below(100),
            })
            .collect(),
        pending,
        params,
        pool: rand_pool_state(rng),
        records: (0..rng.below(4)).map(|_| rand_record(rng)).collect(),
        strategy_name: rand_name(rng),
        strategy_blob: (0..rng.below(40)).map(|_| (rng.next_u64() & 0xff) as u8).collect(),
        mid: rand_mid(rng),
    }
}

/// Where the digested payload begins: walk the header with the public
/// [`Dec`] primitives (magic, format version, three strings, length).
fn payload_offset(bytes: &[u8]) -> usize {
    let mut d = Dec::new(&bytes[8..]);
    d.u32().unwrap();
    d.str().unwrap();
    d.str().unwrap();
    d.str().unwrap();
    d.u64().unwrap();
    bytes.len() - d.remaining()
}

#[test]
fn prop_checkpoint_encode_decode_encode_is_byte_idempotent() {
    cases(60, |rng| {
        let ck = rand_checkpoint(rng);
        let bytes = ck.encode();
        let decoded = Checkpoint::decode(&bytes).unwrap();
        assert_eq!(
            decoded.encode(),
            bytes,
            "serialize→deserialize→serialize must be byte-identical"
        );
    });
}

#[test]
fn prop_truncated_checkpoints_always_err_cleanly() {
    cases(30, |rng| {
        let bytes = rand_checkpoint(rng).encode();
        for _ in 0..16 {
            let cut = rng.below(bytes.len());
            assert!(
                Checkpoint::decode(&bytes[..cut]).is_err(),
                "strict prefix of {cut} bytes must be rejected"
            );
        }
    });
}

#[test]
fn prop_payload_bit_flips_never_survive_the_digest() {
    cases(30, |rng| {
        let bytes = rand_checkpoint(rng).encode();
        let start = payload_offset(&bytes);
        assert!(start < bytes.len(), "every checkpoint has a payload");
        for _ in 0..8 {
            let mut evil = bytes.clone();
            let i = start + rng.below(evil.len() - start);
            evil[i] ^= 1 << rng.below(8);
            assert!(Checkpoint::decode(&evil).is_err(), "flip at byte {i} must be detected");
        }
    });
}

#[test]
fn prop_header_corruption_is_rejected() {
    cases(30, |rng| {
        let bytes = rand_checkpoint(rng).encode();
        // Magic (8 bytes) + format version (4 bytes): any flip is fatal.
        let mut evil = bytes.clone();
        let i = rng.below(12);
        evil[i] ^= 1 << rng.below(8);
        assert!(Checkpoint::decode(&evil).is_err(), "header flip at byte {i}");
    });
}

#[test]
fn prop_pool_snapshot_rewinds_every_mutable_stream() {
    // export_state → draws → import_state(snapshot) → draws again: the
    // second pass must redraw selection, contention, and availability
    // identically on both storage modes — the pool residues a resumed
    // run depends on.
    cases(10, |rng| {
        let (mut eager, mut lazy, n) = pool_pair(rng);
        let probe = MemCoeffs {
            fixed_bytes: 350 * 1_000_000,
            per_sample_bytes: 0,
            params_total: 0,
            params_trainable: 0,
        };
        for pool in [&mut eager, &mut lazy] {
            for _ in 0..rng.below(4) {
                let k = 1 + rng.below(n.min(20));
                let _ = pool.select(k, &probe);
            }
            let snap = pool.export_state();
            let ks: Vec<usize> = (0..5).map(|_| 1 + rng.below(n.min(20))).collect();
            let first: Vec<_> = ks
                .iter()
                .map(|&k| {
                    let s = pool.select(k, &probe);
                    (s.trainers, s.fallback, s.availability)
                })
                .collect();
            pool.import_state(&snap).unwrap();
            let second: Vec<_> = ks
                .iter()
                .map(|&k| {
                    let s = pool.select(k, &probe);
                    (s.trainers, s.fallback, s.availability)
                })
                .collect();
            assert_eq!(first, second, "rewound pool must redraw identically");
        }
        // Storage-mode mismatch is an error, not a corruption.
        let es = eager.export_state();
        assert!(lazy.import_state(&es).is_err(), "eager snapshot into lazy pool");
    });
}

#[test]
fn prop_engine_boundary_checkpoint_is_invisible_to_the_next_round() {
    // Round 0 → checkpoint through the real codec → fresh engine (at a
    // different thread count) → round 1 must equal the uninterrupted
    // engine's round 1 exactly, and the fleet rng must land on the same
    // stream position — across every round policy × churn policy.
    cases(40, |rng| {
        let seed = rng.next_u64();
        let policy = match rng.below(4) {
            0 => RoundPolicy::Sync,
            1 => RoundPolicy::Deadline { secs: rng.uniform(5.0, 200.0) },
            2 => RoundPolicy::OverSelect { extra: rng.below(4) },
            _ => RoundPolicy::Async { buffer_k: 1 + rng.below(5), max_staleness: rng.below(6) },
        };
        let churn = match rng.below(4) {
            0 => ChurnPolicy::None,
            1 => ChurnPolicy::Abort,
            2 => ChurnPolicy::Resume,
            _ => ChurnPolicy::Checkpoint { epochs: 1 + rng.below(6) },
        };
        let works0 = rand_works(rng, true);
        let works1 = rand_works(rng, true);
        let keep = match policy {
            RoundPolicy::OverSelect { .. } => 1 + rng.below(works0.len()),
            _ => usize::MAX,
        };

        let mut e1 = FleetEngine::with_threads(1 + rng.below(4));
        let mut r1 = Rng::new(seed);
        let p0 = e1.simulate_round(0, 0.0, &works0, policy, keep, churn, &mut r1);
        let p1 = e1.simulate_round(1, p0.end_s, &works1, policy, keep, churn, &mut r1);

        let mut e2 = FleetEngine::with_threads(1 + rng.below(4));
        let mut r2 = Rng::new(seed);
        let q0 = e2.simulate_round(0, 0.0, &works0, policy, keep, churn, &mut r2);
        assert_eq!(p0, q0, "same inputs, same round 0");
        let mut ck = rand_checkpoint(rng);
        ck.fleet_rng = r2.state();
        ck.inflight = e2.inflight().to_vec();
        ck.sim_time_s = q0.end_s;
        let decoded = Checkpoint::decode(&ck.encode()).unwrap();
        let mut e3 = FleetEngine::with_threads(1 + rng.below(4));
        e3.restore_inflight(decoded.inflight);
        let mut r3 = Rng::from_state(decoded.fleet_rng);
        let q1 = e3.simulate_round(1, decoded.sim_time_s, &works1, policy, keep, churn, &mut r3);
        assert_eq!(p1, q1, "resume at the boundary must be invisible");
        assert_eq!(r1.state(), r3.state(), "rng stream positions must match");
    });
}

#[test]
fn prop_strategy_blobs_resume_the_schedule_from_any_cut() {
    // Every strategy in the zoo, cut at a random point of a randomized
    // schedule: the blob is save∘load∘save byte-idempotent and the
    // resumed strategy emits the identical remaining phase stream.
    cases(60, |rng| {
        let counts: Vec<u64> =
            (0..2 + rng.below(5)).map(|_| 1_000_000 + rng.below(4_000_000) as u64).collect();
        let v = ModelView::synthetic(&counts);
        let mut cfg = RunConfig::smoke("m");
        cfg.max_rounds_total = 4 + rng.below(40);
        cfg.strategy.elastic_phases =
            if rng.below(2) == 0 { None } else { Some(1 + rng.below(6)) };
        cfg.strategy.freeze_step_cap =
            if rng.below(2) == 0 { None } else { Some(1 + rng.below(8)) };
        let name = ["ProFL", "ParamAware", "LayerFreeze", "Elastic"][rng.below(4)];
        let mut s = strategy_for_resume(name).unwrap();
        let mut last: Option<StepFeedback> = None;
        for _ in 0..rng.below(12) {
            match s.next_phase(&v, &cfg, last.as_ref()) {
                Some(Phase::Train(t)) => {
                    last = Some(StepFeedback {
                        rounds_used: 1 + rng.below(t.max_rounds.max(1)),
                        froze: true,
                    });
                }
                Some(_) => last = None,
                None => break,
            }
        }
        let blob = s.save_state();
        let mut r = strategy_for_resume(name).unwrap();
        r.load_state(&blob).unwrap();
        assert_eq!(r.save_state(), blob, "{name}: save∘load∘save byte-idempotent");
        let mut last2 = last;
        let mut guard = 0;
        loop {
            let a = s.next_phase(&v, &cfg, last.as_ref());
            let b = r.next_phase(&v, &cfg, last2.as_ref());
            assert_eq!(a, b, "{name}: continuation diverged");
            match a {
                Some(Phase::Train(t)) => {
                    let f = StepFeedback {
                        rounds_used: 1 + rng.below(t.max_rounds.max(1)),
                        froze: true,
                    };
                    last = Some(f);
                    last2 = Some(f);
                }
                Some(_) => {
                    last = None;
                    last2 = None;
                }
                None => break,
            }
            guard += 1;
            assert!(guard < 200, "{name}: schedule did not terminate");
        }
        // A mutated blob may or may not decode — but it must never panic.
        let mut evil = blob.clone();
        if !evil.is_empty() {
            let i = rng.below(evil.len());
            evil[i] ^= 1 << rng.below(8);
            let _ = strategy_for_resume(name).unwrap().load_state(&evil);
        }
    });
}

#[test]
fn prop_config_fingerprint_round_trips_and_detects_tampering() {
    cases(30, |rng| {
        let mut cfg = RunConfig::smoke("m");
        cfg.seed = rng.next_u64();
        cfg.dirichlet_alpha =
            if rng.below(2) == 0 { None } else { Some(rng.uniform(0.05, 5.0)) };
        cfg.fleet.lazy_pool = rng.below(2) == 0;
        cfg.fleet.round_policy =
            ["sync", "deadline", "over-select", "async"][rng.below(4)].into();
        cfg.fleet.churn_policy = ["none", "abort", "resume", "checkpoint"][rng.below(4)].into();
        let mut ck = rand_checkpoint(rng);
        ck.config_json = profl::telemetry::config_value(&cfg).to_json();
        ck.config_sha256 = profl::telemetry::config_sha256(&cfg);
        let resolved = ck.resolve_config().unwrap();
        assert_eq!(profl::telemetry::config_sha256(&resolved), ck.config_sha256);
        // Hash-relevant tampering: rejected, naming the embedded hash.
        let mut other = resolved.clone();
        other.seed ^= 1;
        let err = ck.verify_config(&other).unwrap_err().to_string();
        assert!(err.contains("config fingerprint mismatch"), "got: {err}");
        assert!(err.contains(&ck.config_sha256), "must name the embedded hash: {err}");
        // Hash-neutral knobs: legal to change on resume by construction.
        let mut neutral = resolved;
        neutral.fleet.threads += 3;
        neutral.checkpoint = Some("elsewhere-{round}.ckpt".into());
        neutral.checkpoint_every = 7;
        ck.verify_config(&neutral).unwrap();
    });
}
