//! Offline stand-in for the `xla` PJRT bindings (xla_extension 0.5.1).
//!
//! The coordinator crate (`profl`) executes AOT-lowered HLO artifacts
//! through the PJRT C API. That native backend cannot be built in an
//! offline container, so this crate provides the exact API surface the
//! coordinator uses:
//!
//! * the pure-Rust parts — [`Literal`] construction and readback — are
//!   fully functional, so everything up to (but excluding) device
//!   execution is testable offline;
//! * the PJRT entry points ([`PjRtClient::cpu`], compile, execute,
//!   [`HloModuleProto::from_text_file`]) return a descriptive [`Error`],
//!   which surfaces as "PJRT runtime unavailable" the moment a run
//!   actually needs artifacts.
//!
//! To run against real hardware, replace the `xla = { path = "xla-stub" }`
//! dependency in `rust/Cargo.toml` with the real bindings (LaurentMazare's
//! `xla-rs` exposes this same interface); no coordinator code changes.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring the real bindings' string-y errors; implements
/// `std::error::Error` so `?` converts into `anyhow::Error` at call sites.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (built with the offline `xla` stub; \
         swap in the real bindings in rust/Cargo.toml to execute artifacts)"
    ))
}

/// Element dtypes the coordinator uses (both 4 bytes wide).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Scalar types readable out of a [`Literal`].
pub trait NativeType: Sized + Copy {
    const TY: ElementType;
    fn from_ne(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_ne(bytes: [u8; 4]) -> Self {
        f32::from_ne_bytes(bytes)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_ne(bytes: [u8; 4]) -> Self {
        i32::from_ne_bytes(bytes)
    }
}

/// Host-side tensor value: dtype + shape + native-endian bytes.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    /// A rank-0 f32 literal (learning rates and friends).
    pub fn scalar(v: f32) -> Literal {
        Literal { ty: ElementType::F32, shape: Vec::new(), data: v.to_ne_bytes().to_vec() }
    }

    /// Build a literal from raw bytes (the coordinator's zero-copy entry
    /// point); validates that the byte length matches the shape.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = shape.iter().product();
        if elems * 4 != data.len() {
            return Err(Error(format!(
                "shape {shape:?} wants {} bytes, got {}",
                elems * 4,
                data.len()
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), data: data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Read the literal back as a host vector; dtype-checked.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!("dtype mismatch: literal is {:?}", self.ty)));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_ne([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Destructure a tuple literal. Tuples only come out of device
    /// execution, which the stub cannot perform.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub: parsing requires the native tooling).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({:?})", path.as_ref())))
    }
}

/// An XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle (stub: construction fails so callers error early
/// with a clear message instead of at first execution).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled-and-loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data.to_vec());
        assert!(lit.to_vec::<i32>().is_err(), "dtype-checked readback");
    }

    #[test]
    fn literal_shape_validation() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4])
            .is_err());
    }

    #[test]
    fn scalar_is_rank_zero() {
        let lit = Literal::scalar(0.5);
        assert!(lit.shape().is_empty());
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![0.5]);
    }

    #[test]
    fn pjrt_entry_points_fail_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("PJRT runtime unavailable"), "{err}");
        assert!(HloModuleProto::from_text_file("nope.hlo").is_err());
    }
}
