//! L3 hot-path microbenches: aggregation bandwidth, effective-movement
//! computation, corner slicing, synthetic batch generation, store init.
//!
//! These are the per-round coordinator costs that must stay negligible
//! next to the PJRT executions (DESIGN.md §Perf: aggregation is
//! memcpy-bound; target multi-GB/s on one core).
//!
//!   cargo bench --bench l3_hotpaths

use profl::aggregate::{Aggregator, SlicedAggregator};
use profl::bench_util::{bench, throughput};
use profl::data::{partition, Partition, SyntheticDataset};
use profl::freezing::EffectiveMovement;
use profl::rng::Rng;
use profl::store::{ParamStore, Tensor};
use std::collections::BTreeMap;

fn big_store(n_params: usize, elems_each: usize) -> (ParamStore, Vec<String>) {
    let shapes: BTreeMap<String, Vec<usize>> =
        (0..n_params).map(|i| (format!("p{i:03}"), vec![elems_each])).collect();
    let names: Vec<String> = shapes.keys().cloned().collect();
    (ParamStore::init(&shapes, 1), names)
}

/// The pre-arena aggregation algorithm (vec-of-vecs accumulators, one
/// allocation per tensor), kept verbatim as the baseline the contiguous
/// arena is measured against. Must never be faster than `Aggregator` at
/// ≥100-tensor models (the `docs/PERFORMANCE.md` acceptance bar).
struct NestedReference {
    acc: Vec<Vec<f32>>,
    total_weight: f64,
}

impl NestedReference {
    fn new(sizes: &[usize]) -> Self {
        NestedReference { acc: sizes.iter().map(|&n| vec![0.0; n]).collect(), total_weight: 0.0 }
    }

    fn add(&mut self, tensors: &[Vec<f32>], weight: f64) {
        let w = weight as f32;
        for (a, t) in self.acc.iter_mut().zip(tensors) {
            for (x, v) in a.iter_mut().zip(t) {
                *x += w * v;
            }
        }
        self.total_weight += weight;
    }

    fn finish(mut self) -> Vec<Vec<f32>> {
        let inv = 1.0 / self.total_weight as f32;
        for a in &mut self.acc {
            for x in a.iter_mut() {
                *x *= inv;
            }
        }
        self.acc
    }
}

/// Arena-vs-nested comparison at one model granularity: `n_tensors`
/// tensors of `elems` scalars each, 10 clients.
fn bench_arena_vs_nested(tag: &str, n_tensors: usize, elems: usize) {
    let (mut store, names) = big_store(n_tensors, elems);
    let mut rng = Rng::new(7);
    let updates: Vec<Vec<Vec<f32>>> = (0..10)
        .map(|_| names.iter().map(|_| (0..elems).map(|_| rng.normal()).collect()).collect())
        .collect();
    let sizes: Vec<usize> = vec![elems; n_tensors];
    // Bit-identity witness before racing: the SIMD-chunked arena must
    // reproduce the scalar nested reference exactly (elementwise kernels
    // never reassociate — see `aggregate::simd`).
    {
        let mut check = store.clone();
        let mut agg = Aggregator::new(&names, &check).unwrap();
        for u in &updates {
            agg.add(u, 1.0);
        }
        agg.finish(&mut check).unwrap();
        let mut nested = NestedReference::new(&sizes);
        for u in &updates {
            nested.add(u, 1.0);
        }
        let want = nested.finish();
        for (i, name) in names.iter().enumerate() {
            let got = &check.get(name).unwrap().data;
            for (g, r) in got.iter().zip(&want[i]) {
                assert_eq!(g.to_bits(), r.to_bits(), "{tag}/{name}: arena diverged from scalar");
            }
        }
    }
    bench(&format!("fedavg_arena_{tag}"), 3, 20, || {
        let mut agg = Aggregator::new(&names, &store).unwrap();
        for u in &updates {
            agg.add(u, 1.0);
        }
        agg.finish(&mut store).unwrap();
    });
    bench(&format!("fedavg_nested_ref_{tag}"), 3, 20, || {
        let mut agg = NestedReference::new(&sizes);
        for u in &updates {
            agg.add(u, 1.0);
        }
        std::hint::black_box(agg.finish());
    });
}

fn main() {
    // ---- FedAvg aggregation: 10 clients × 1M scalars -----------------------
    let (mut store, names) = big_store(32, 32_768); // ≈1M f32 total
    let total_elems: usize = 32 * 32_768;
    let mut rng = Rng::new(2);
    let updates: Vec<Vec<Vec<f32>>> = (0..10)
        .map(|_| names.iter().map(|_| (0..32_768).map(|_| rng.normal()).collect()).collect())
        .collect();
    let r = bench("fedavg_10clients_1M_scalars", 3, 20, || {
        let mut agg = Aggregator::new(&names, &store).unwrap();
        for u in &updates {
            agg.add(u, 1.0);
        }
        agg.finish(&mut store).unwrap();
    });
    println!(
        "  -> {:.2} GB/s aggregated\n",
        throughput(&r, total_elems * 10 * 4) / 1e9
    );

    // ---- Contiguous arena vs the historical nested layout ------------------
    // Small models must not regress; 100+-tensor models (where per-tensor
    // allocation + pointer chasing dominate) are where the arena wins.
    bench_arena_vs_nested("8t_x_32k", 8, 32_768);
    bench_arena_vs_nested("128t_x_2k", 128, 2_048);
    bench_arena_vs_nested("256t_x_1k", 256, 1_024);
    // Ragged tensor length (not a multiple of the 8-lane chunk): the
    // scalar-tail path must neither regress nor diverge.
    bench_arena_vs_nested("96t_x_1339_ragged", 96, 1_339);
    println!();

    // ---- HeteroFL sliced aggregation ---------------------------------------
    let shapes: Vec<Vec<usize>> = (0..16).map(|_| vec![3, 3, 64, 64]).collect();
    let sub_shapes: Vec<Vec<usize>> = shapes.iter().map(|_| vec![3, 3, 32, 32]).collect();
    let shapes_map: BTreeMap<String, Vec<usize>> =
        shapes.iter().enumerate().map(|(i, s)| (format!("c{i:02}"), s.clone())).collect();
    let cnames: Vec<String> = shapes_map.keys().cloned().collect();
    let mut cstore = ParamStore::init(&shapes_map, 3);
    let subs: Vec<Vec<f32>> =
        sub_shapes.iter().map(|s| vec![0.5; s.iter().product()]).collect();
    bench("heterofl_sliced_agg_16convs", 3, 20, || {
        let mut agg = SlicedAggregator::new(&cnames, &cstore).unwrap();
        for _ in 0..8 {
            agg.add(&sub_shapes, &subs, 1.0);
        }
        agg.finish(&mut cstore).unwrap();
    });

    // ---- Effective movement over a 131k-param block ------------------------
    let mut em = EffectiveMovement::new(3);
    let mut v = vec![0.0f32; 131_712]; // ResNet18-mini block 4
    let mut erng = Rng::new(4);
    bench("effective_movement_block4", 3, 30, || {
        for x in v.iter_mut() {
            *x += erng.normal() * 0.01;
        }
        let _ = em.push(&v);
    });

    // ---- Corner slicing (HeteroFL client dispatch) --------------------------
    let t = Tensor { shape: vec![3, 3, 64, 64], data: vec![1.0; 3 * 3 * 64 * 64] };
    bench("slice_corner_conv64_to_32", 3, 50, || {
        let _ = t.slice_corner(&[3, 3, 32, 32]).unwrap();
    });

    // ---- Synthetic batch generation ----------------------------------------
    let data = SyntheticDataset::new(10, 5);
    let mut shards = partition(&data, 4, 400, Partition::Dirichlet { alpha: 1.0 }, 5);
    let (mut xs, mut ys) = (Vec::new(), Vec::new());
    bench("fill_batches_2x16_images", 3, 30, || {
        shards[0].fill_batches(&data, 2, 16, &mut xs, &mut ys);
    });

    // ---- Store init (run setup cost) ----------------------------------------
    let shapes: BTreeMap<String, Vec<usize>> =
        (0..64).map(|i| (format!("w{i:02}"), vec![3, 3, 16, 16])).collect();
    bench("param_store_init_64tensors", 2, 20, || {
        let _ = ParamStore::init(&shapes, 9);
    });
}
