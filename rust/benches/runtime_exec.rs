//! Runtime bench: PJRT execution latency per artifact kind — the dominant
//! per-round cost. Measures the full L3-side path: literal creation from
//! the store, execution, output unpacking.
//!
//!   cargo bench --bench runtime_exec

use profl::bench_util::bench;
use profl::runtime::{literal_f32, literal_i32, Runtime};
use profl::store::ParamStore;

fn main() {
    let dir = profl::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&dir).unwrap();
    let tag = "resnet18_w8_c10";
    let model = rt.model(tag).unwrap().clone();
    let store = ParamStore::init(&model.params, 1);
    let scan = rt.manifest.scan_steps;
    let batch = rt.manifest.train_batch;
    let eval_batch = rt.manifest.eval_batch;

    for art_name in ["train_t1", "train_t4", "train_full", "distill_t2"] {
        let art = rt.load(tag, art_name).unwrap();
        let params = rt.param_literals(&art.meta, &store).unwrap();
        let xs = literal_f32(&[scan, batch, 32, 32, 3], &vec![0.1; scan * batch * 3072]).unwrap();
        let ys = literal_i32(&[scan, batch], &vec![1; scan * batch]).unwrap();
        let lr = xla::Literal::scalar(0.01f32);
        bench(&format!("exec_{art_name}"), 2, 10, || {
            let mut inputs: Vec<&xla::Literal> = params.iter().collect();
            inputs.push(&xs);
            if art_name != "distill_t2" {
                inputs.push(&ys);
            }
            inputs.push(&lr);
            let outs = art.execute(&inputs).unwrap();
            let _ = Runtime::unpack_train_outputs(&art.meta, outs).unwrap();
        });
    }

    // Eval path
    let art = rt.load(tag, "eval_t4").unwrap();
    let params = rt.param_literals(&art.meta, &store).unwrap();
    let x = literal_f32(&[eval_batch, 32, 32, 3], &vec![0.1; eval_batch * 3072]).unwrap();
    let y = literal_i32(&[eval_batch], &vec![1; eval_batch]).unwrap();
    bench("exec_eval_t4_batch", 2, 10, || {
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.push(&x);
        inputs.push(&y);
        let _ = art.execute(&inputs).unwrap();
    });

    // Literal marshalling alone (the Rust-side overhead to minimize)
    let art = rt.load(tag, "train_t4").unwrap();
    bench("param_literals_train_t4", 2, 30, || {
        let _ = rt.param_literals(&art.meta, &store).unwrap();
    });
}
