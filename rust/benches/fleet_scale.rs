//! Fleet-scale round-scheduling bench: **is round cost independent of
//! fleet size?** Sweeps fleet sizes × round policies × churn policies
//! over the lazy client pool and the scratch-reusing fleet engine —
//! entirely artifact-free, so it runs anywhere (CI smoke mode included).
//!
//! Each entry simulates real scheduling rounds end to end (cohort
//! sampling with in-flight exclusion → work building → discrete-event
//! simulation) and reports per-round wall time plus allocation counters
//! from a counting global allocator — the peak-RSS proxy that witnesses
//! the lazy pool's O(materialized) memory contract. Results append to
//! stdout and, with `--json PATH`, to a `BENCH_fleet.json` document
//! (`make bench-json`); see `docs/PERFORMANCE.md` for how to read it.
//!
//! A second matrix drives the cohort-merge path itself: `merge-pooled`
//! vs `merge-cloning` rows × merge threads {1, 4, 8} through
//! [`Aggregator`], asserting bit-identical stores across every config
//! and O(1) tensor-buffer allocations per round on the pooled path
//! (`--warmup N` pins the warm-up for `scripts/perf_ab.sh` A/B runs).
//!
//!   cargo bench --bench fleet_scale                    # full sweep (1e3..1e6)
//!   cargo bench --bench fleet_scale -- --smoke         # CI-sized (1e3, 1e4)
//!   cargo bench --bench fleet_scale -- --json BENCH_fleet.json

use profl::aggregate::{Aggregator, TensorPool};
use profl::bench_util::BenchResult;
use profl::cli::Args;
use profl::clients::ClientPool;
use profl::data::{Partition, SyntheticDataset};
use profl::fleet::{ChurnPolicy, ClientWork, FleetEngine, FleetProfileConfig, RoundPolicy};
use profl::json::Value;
use profl::manifest::MemCoeffs;
use profl::rng::Rng;
use profl::store::ParamStore;
use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Counting allocator: bytes/calls + live/peak gauges (peak-RSS proxy).
// ---------------------------------------------------------------------------

static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);
static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static LIVE_BYTES: AtomicU64 = AtomicU64::new(0);
static PEAK_BYTES: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

impl CountingAlloc {
    fn on_alloc(size: usize) {
        ALLOC_BYTES.fetch_add(size as u64, Ordering::Relaxed);
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        let live = LIVE_BYTES.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
        PEAK_BYTES.fetch_max(live, Ordering::Relaxed);
    }

    fn on_dealloc(size: usize) {
        LIVE_BYTES.fetch_sub(size as u64, Ordering::Relaxed);
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::on_alloc(layout.size());
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        Self::on_dealloc(layout.size());
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        Self::on_dealloc(layout.size());
        Self::on_alloc(new_size);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Snapshot of the allocation counters.
#[derive(Clone, Copy)]
struct AllocSnap {
    bytes: u64,
    calls: u64,
    peak: u64,
}

fn alloc_snap() -> AllocSnap {
    AllocSnap {
        bytes: ALLOC_BYTES.load(Ordering::Relaxed),
        calls: ALLOC_CALLS.load(Ordering::Relaxed),
        peak: PEAK_BYTES.load(Ordering::Relaxed),
    }
}

/// Reset the peak gauge to the current live level (per-entry peaks).
fn reset_peak() {
    PEAK_BYTES.store(LIVE_BYTES.load(Ordering::Relaxed), Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// The simulated workload (mirrors examples/churn_sweep.rs, lazily).
// ---------------------------------------------------------------------------

/// ResNet18-ish artifact proxy: 11 Mparams / 44 MB per exchange.
fn artifact_mem() -> MemCoeffs {
    MemCoeffs {
        fixed_bytes: 0,
        per_sample_bytes: 0,
        params_total: 11_000_000,
        params_trainable: 11_000_000,
    }
}

fn works_for(pool: &mut ClientPool, ids: &[usize], start: f64) -> Vec<ClientWork> {
    let mem = artifact_mem();
    let bytes = 44_000_000u64;
    ids.iter()
        .map(|&cid| {
            let c = pool.client_mut(cid);
            let p = &c.profile;
            ClientWork {
                id: cid,
                ready_s: p.trace.next_online(start),
                down_s: p.down_time_s(bytes),
                train_s: p.train_time_s(c.shard.num_samples(), &mem),
                up_s: p.up_time_s(bytes),
                dropout_p: p.dropout_p,
                trace: p.trace,
            }
        })
        .collect()
}

struct EntryResult {
    fleet: usize,
    policy: &'static str,
    churn: &'static str,
    threads: usize,
    build_ms: f64,
    stats: profl::bench_util::BenchStats,
    alloc_bytes_per_round: u64,
    allocs_per_round: u64,
    peak_live_bytes: u64,
    peak_materialized: usize,
}

#[allow(clippy::too_many_arguments)]
fn run_entry(
    fleet: usize,
    cohort: usize,
    rounds: usize,
    warmup: usize,
    policy_name: &'static str,
    policy: RoundPolicy,
    churn_name: &'static str,
    churn: ChurnPolicy,
    threads: usize,
    seed: u64,
) -> EntryResult {
    // Duty-cycled mobile fleet so churn actually fires mid-span.
    let mut profile = FleetProfileConfig::named("mobile").expect("named profile");
    profile.period_s = 240.0;
    profile.duty = 0.5;
    profile.dropout_p = 0.05;

    let data = SyntheticDataset::new(10, seed);
    let t0 = Instant::now();
    // Resident cap ≫ cohort: evictions stay off the steady-state path.
    let mut pool = ClientPool::build_lazy(
        fleet,
        fleet.saturating_mul(10),
        &data,
        Partition::Iid,
        profl::memory::MemoryConfig::default(),
        &profile,
        seed,
        cohort * 8,
    );
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mem = artifact_mem();
    let keep = usize::MAX;
    // Thread count changes wall time only, never the plan (bit-identical
    // at any count — the docs/SIMULATION.md determinism guarantee).
    let mut engine = FleetEngine::with_threads(threads);
    let mut fleet_rng = Rng::new(seed ^ 0xf1ee_7c10);
    let mut start = 0.0f64;
    let mut samples = Vec::with_capacity(rounds);
    reset_peak();
    let before = alloc_snap();
    for round in 0..warmup + rounds {
        let busy: Vec<usize> = engine.inflight().iter().map(|u| u.client).collect();
        let t = Instant::now();
        let sel = pool.select_excluding(cohort, &mem, &busy);
        let works = works_for(&mut pool, &sel.trainers, start);
        let plan = engine.simulate_round(round, start, &works, policy, keep, churn, &mut fleet_rng);
        let dt = t.elapsed();
        start = plan.end_s;
        if round >= warmup {
            samples.push(dt);
        }
    }
    let after = alloc_snap();

    let name =
        format!("fleet={fleet:>9} {policy_name:<12} churn={churn_name:<6} threads={threads}");
    let result = BenchResult::new(name, samples);
    result.report();
    let total = (warmup + rounds) as u64;
    EntryResult {
        fleet,
        policy: policy_name,
        churn: churn_name,
        threads,
        build_ms,
        stats: result.stats(),
        alloc_bytes_per_round: (after.bytes - before.bytes) / total,
        allocs_per_round: (after.calls - before.calls) / total,
        peak_live_bytes: after.peak,
        peak_materialized: pool.peak_materialized(),
    }
}

// ---------------------------------------------------------------------------
// Cohort-merge workload: serial-vs-sharded × pooled-vs-cloning A/B rows.
// ---------------------------------------------------------------------------

/// Tensors in the synthetic merge model (fixed: the A/B story varies
/// merge threads and buffer handling, never the model shape).
const MERGE_TENSORS: usize = 16;
/// Cohort updates merged per round.
const MERGE_CLIENTS: usize = 32;

/// Deterministic per-(round, client) update payload: identical values at
/// any merge thread count and in both buffer modes, so the store-bit
/// identity assertion in `main` is meaningful.
fn fill_update(bufs: &mut Vec<Vec<f32>>, sizes: &[usize], seed: u64, round: usize, c: usize) {
    let mut rng = Rng::new(seed ^ ((round as u64) << 20) ^ c as u64);
    bufs.resize_with(sizes.len(), Vec::new);
    for (buf, &n) in bufs.iter_mut().zip(sizes) {
        buf.clear();
        buf.extend((0..n).map(|_| rng.f32() - 0.5));
    }
}

/// FNV-1a over the store's f32 bit patterns: the bit-identity witness.
fn store_bits(store: &ParamStore, names: &[String]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for name in names {
        for &v in &store.get(name).expect("merge tensor").data {
            h ^= u64::from(v.to_bits());
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// One merge A/B row: `rounds` cohort merges through [`Aggregator`] at
/// `threads` merge workers, either recycling update buffers through a
/// [`TensorPool`] (`pooled`) or cloning borrowed slices per client (the
/// historical path). Unlike the fleet rows, the allocation counters here
/// cover only the measured rounds, so pool warm-up misses don't pollute
/// the O(1)-allocs witness. Returns the row plus the final store's bit
/// hash for the cross-config determinism assertion.
fn run_merge_entry(
    elements: usize,
    rounds: usize,
    warmup: usize,
    pooled: bool,
    threads: usize,
    seed: u64,
) -> (EntryResult, u64) {
    let per = (elements / MERGE_TENSORS).max(1);
    let names: Vec<String> = (0..MERGE_TENSORS).map(|i| format!("layer{i:02}.w")).collect();
    let mut shapes = BTreeMap::new();
    for n in &names {
        shapes.insert(n.clone(), vec![per]);
    }
    let sizes = vec![per; MERGE_TENSORS];
    let t0 = Instant::now();
    let mut store = ParamStore::init(&shapes, seed);
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut pool = TensorPool::new(MERGE_CLIENTS + 4);
    let mut samples = Vec::with_capacity(rounds);
    reset_peak();
    let mut before = alloc_snap();
    for round in 0..warmup + rounds {
        if round == warmup {
            before = alloc_snap();
        }
        let t = Instant::now();
        let mut agg = Aggregator::new(&names, &store).expect("merge aggregator");
        agg.set_merge_threads(threads);
        for c in 0..MERGE_CLIENTS {
            let weight = (c + 1) as f64;
            if pooled {
                let mut bufs = pool.acquire();
                fill_update(&mut bufs, &sizes, seed, round, c);
                agg.add_owned(bufs, weight);
            } else {
                let mut bufs = Vec::new();
                fill_update(&mut bufs, &sizes, seed, round, c);
                agg.add(&bufs, weight);
            }
        }
        let recycle = if pooled { Some(&mut pool) } else { None };
        agg.finish_stats(&mut store, recycle).expect("merge finish");
        let dt = t.elapsed();
        if round >= warmup {
            samples.push(dt);
        }
    }
    let after = alloc_snap();

    let policy: &'static str = if pooled { "merge-pooled" } else { "merge-cloning" };
    let name = format!("merge={elements:>9} {policy:<13} threads={threads}");
    let result = BenchResult::new(name, samples);
    result.report();
    let measured = rounds.max(1) as u64;
    let entry = EntryResult {
        fleet: elements,
        policy,
        churn: "none",
        threads,
        build_ms,
        stats: result.stats(),
        alloc_bytes_per_round: (after.bytes - before.bytes) / measured,
        allocs_per_round: (after.calls - before.calls) / measured,
        peak_live_bytes: after.peak,
        peak_materialized: 0,
    };
    (entry, store_bits(&store, &names))
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).expect("args");
    let smoke = args.flag("smoke");
    let json_path = args.get("json").map(String::from);
    let seed: u64 = args.parse_opt("seed").expect("seed").unwrap_or(42);
    let cohort: usize = args.parse_opt("cohort").expect("cohort").unwrap_or(50);
    let (fleets, rounds, warmup): (&[usize], usize, usize) = if smoke {
        (&[1_000, 10_000], 4, 1)
    } else {
        (&[1_000, 100_000, 1_000_000], 8, 2)
    };
    // Pinned warmup for A/B runs (`scripts/perf_ab.sh`): identical warmup
    // on both sides keeps cold-path noise out of the comparison.
    let warmup: usize = args.parse_opt("warmup").expect("warmup").unwrap_or(warmup);
    // Span-planner thread matrix: threads=1 is the inline baseline; the
    // other columns witness the wall-clock win of parallel planning at
    // identical (bit-for-bit) round plans.
    let threads_matrix: &[usize] = &[1, 4, 8];

    let buffer_k = (cohort / 2).max(1);
    let policies: [(&'static str, RoundPolicy); 3] = [
        ("sync", RoundPolicy::Sync),
        ("async", RoundPolicy::Async { buffer_k, max_staleness: 8 }),
        ("deadline:120", RoundPolicy::Deadline { secs: 120.0 }),
    ];
    let churns: [(&'static str, ChurnPolicy); 2] =
        [("none", ChurnPolicy::None), ("resume", ChurnPolicy::Resume)];

    println!(
        "fleet_scale: cohort={cohort} rounds={rounds} (+{warmup} warmup) seed={seed} \
         fleets={fleets:?}\n"
    );
    let mut entries = Vec::new();
    for &fleet in fleets {
        for (pname, policy) in policies {
            for (cname, churn) in churns {
                for &threads in threads_matrix {
                    let e = run_entry(
                        fleet, cohort, rounds, warmup, pname, policy, cname, churn, threads,
                        seed,
                    );
                    // The memory-wall witness: simulating rounds over a fleet
                    // orders of magnitude larger than the cohort must not
                    // materialize the fleet. (Small fleets are skipped — the
                    // resident cap itself can exceed them.)
                    if fleet >= cohort * 100 {
                        assert!(
                            e.peak_materialized * 10 < fleet,
                            "fleet {fleet}: peak materialized {} is not ≪ fleet size",
                            e.peak_materialized
                        );
                    }
                    entries.push(e);
                }
            }
        }
        println!();
    }

    // Cohort-merge A/B matrix: the sharded-replay + buffer-pool rows.
    // Element count is the same in smoke and full mode so the advisory
    // perf_compare step always finds intersecting keys.
    let merge_elements = 160_000;
    println!(
        "merge: elements={merge_elements} clients={MERGE_CLIENTS} threads={threads_matrix:?}"
    );
    let mut merge_rows = Vec::new();
    let mut merge_bits = Vec::new();
    for pooled in [true, false] {
        for &threads in threads_matrix {
            let (e, bits) = run_merge_entry(merge_elements, rounds, warmup, pooled, threads, seed);
            merge_rows.push(e);
            merge_bits.push(bits);
        }
    }
    // Determinism witness: every merge thread count and both buffer
    // modes must converge the store to bit-identical values.
    assert!(
        merge_bits.iter().all(|&b| b == merge_bits[0]),
        "sharded/pooled merge diverged from the serial bits: {merge_bits:#x?}"
    );
    // The zero-copy witness: with the pool primed, the serial pooled row
    // allocates O(1) buffers per round — fixed arena/op bookkeeping, not
    // the O(clients × tensors) buffer churn of the cloning path.
    let find = |policy: &str| {
        merge_rows
            .iter()
            .find(|e| e.policy == policy && e.threads == 1)
            .expect("serial merge row")
    };
    let (pooled_serial, cloning_serial) = (find("merge-pooled"), find("merge-cloning"));
    assert!(
        pooled_serial.allocs_per_round < 64,
        "pooled merge allocates per-client buffers: {} allocs/round",
        pooled_serial.allocs_per_round
    );
    assert!(
        pooled_serial.allocs_per_round * 4 < cloning_serial.allocs_per_round,
        "pooled merge ({} allocs/round) does not beat cloning ({} allocs/round)",
        pooled_serial.allocs_per_round,
        cloning_serial.allocs_per_round
    );
    entries.extend(merge_rows);
    println!();

    if let Some(path) = json_path {
        let doc = to_json(cohort, rounds, seed, &entries);
        std::fs::write(&path, doc.to_json()).expect("write bench json");
        println!("wrote {path}");
    }
}

fn to_json(cohort: usize, rounds: usize, seed: u64, entries: &[EntryResult]) -> Value {
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Value::Str("fleet_scale".into()));
    root.insert("schema".into(), Value::Num(2.0));
    root.insert("cohort".into(), Value::Num(cohort as f64));
    root.insert("rounds".into(), Value::Num(rounds as f64));
    root.insert("seed".into(), Value::Num(seed as f64));
    // `native` marks numbers actually measured by this Rust binary — a
    // twin-produced artifact must never carry this stamp (the runner
    // field is how consumers tell them apart).
    root.insert("runner".into(), Value::Str("native".into()));
    root.insert("regenerate".into(), Value::Str("make bench-json".into()));
    let arr: Vec<Value> = entries
        .iter()
        .map(|e| {
            let mut o = BTreeMap::new();
            o.insert("fleet".into(), Value::Num(e.fleet as f64));
            o.insert("policy".into(), Value::Str(e.policy.into()));
            o.insert("churn".into(), Value::Str(e.churn.into()));
            o.insert("threads".into(), Value::Num(e.threads as f64));
            o.insert("build_ms".into(), Value::Num(e.build_ms));
            o.insert("mean_ns".into(), Value::Num(e.stats.mean_ns as f64));
            o.insert("median_ns".into(), Value::Num(e.stats.median_ns as f64));
            o.insert("p95_ns".into(), Value::Num(e.stats.p95_ns as f64));
            o.insert("min_ns".into(), Value::Num(e.stats.min_ns as f64));
            o.insert("max_ns".into(), Value::Num(e.stats.max_ns as f64));
            o.insert("alloc_bytes_per_round".into(), Value::Num(e.alloc_bytes_per_round as f64));
            o.insert("allocs_per_round".into(), Value::Num(e.allocs_per_round as f64));
            o.insert("peak_live_bytes".into(), Value::Num(e.peak_live_bytes as f64));
            o.insert("peak_materialized".into(), Value::Num(e.peak_materialized as f64));
            Value::Obj(o)
        })
        .collect();
    root.insert("entries".into(), Value::Arr(arr));
    Value::Obj(root)
}
