#!/usr/bin/env bash
# Pre-PR gate for the Rust L3 coordinator (see ROADMAP.md):
#   fmt → clippy (warnings are errors) → docs (warnings are errors) → tests.
#
# Run from anywhere: `./rust/check.sh` or `make check`.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -D warnings"
# No allowlist needed today; append `-A clippy::<lint>` here (with a
# comment) if a pre-existing lint must be grandfathered.
cargo clippy --all-targets -- -D warnings

echo "== cargo doc --no-deps -D warnings (make docs)"
# The crate carries #![warn(missing_docs)], so this step keeps every
# public item documented (and every intra-doc link resolving). Scoped
# to the profl crate: xla-stub stands in for an external dependency.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p profl --quiet

echo "== cargo build --benches (bench targets must not rot)"
# Clippy already lints them; this guarantees the bench binaries *link*
# (a bench-only dependency or dead registration shows up here, not at
# the next perf investigation).
cargo build --benches

echo "== cargo test -q (PROFL_THREADS=4)"
# The fleet engine's default worker count honors PROFL_THREADS, so this
# runs the whole suite — golden traces included — with the parallel span
# planner on 4 workers. Results are bit-identical at any thread count
# (docs/SIMULATION.md); the explicit thread-matrix tests additionally
# compare threads 1 vs 4 vs 8 head-to-head.
PROFL_THREADS=4 cargo test -q

# Telemetry smoke gate: the tour binary emits a JSONL stream + manifest
# and validates both in-process (exits non-zero on any contract
# violation) — keeps the observability surface from bit-rotting.
echo "== telemetry smoke (make telemetry-smoke)"
cargo run --release --quiet --example telemetry_tour -- --smoke

# Strategy smoke gate: schedule-degeneracy assertion (trait port ≡
# legacy ProFL schedule) plus the four-strategy head-to-head with
# footprint/dispatch self-validation (exits non-zero on any violation).
echo "== strategy smoke (make strategy-smoke)"
cargo run --release --quiet --example strategy_zoo -- --smoke

# Checkpoint/resume smoke gate: kill a fleet run at every round
# boundary, resume from the on-disk checkpoint file, byte-compare
# against the uninterrupted trace, and prove tampered/drifted
# checkpoints are rejected (exits non-zero on any violation; see
# docs/CHECKPOINT.md).
echo "== resume smoke (make resume-smoke)"
cargo run --release --quiet --example resume_tour -- --smoke

# The full test run above already includes the golden-trace suite; this
# named pass keeps a loud, greppable signal when an engine change shifts
# an event trace (regenerate with `make test-golden-update`). Run under
# PROFL_THREADS=4 so the committed goldens are explicitly held to the
# any-thread-count determinism guarantee.
echo "== golden traces at 4 planner threads (make test-golden)"
PROFL_THREADS=4 cargo test -q --test golden_trace

echo "check: OK"
