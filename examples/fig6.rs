//! Figure 6 — per-block training memory usage and participation rate.
//!
//! Pure memory-model experiment (no training): for each ProFL step
//! artifact, report the paper-twin footprint at the accounting batch and
//! the fraction of a 100-client U[100,900]MB fleet that can train it.
//! Expected shape: early blocks cost the most memory (activations) and
//! admit the fewest clients; the output layer admits ~everyone.
//!
//!   cargo run --release --example fig6

use anyhow::Result;
use profl::clients::ClientPool;
use profl::config::RunConfig;
use profl::data::SyntheticDataset;
use profl::harness::{save_text, ExpOpts};
use profl::Runtime;

fn main() -> Result<()> {
    let opts = ExpOpts::from_env()?;
    let rt = Runtime::new(&profl::artifacts_dir())?;
    let models = opts
        .models
        .clone()
        .unwrap_or_else(|| vec!["resnet18_w8_c10".into(), "resnet34_w8_c10".into()]);

    let mut out = String::from("Fig 6 — memory usage + participation rate per trained block\n");
    for model in &models {
        let cfg = RunConfig { model_tag: model.clone(), ..Default::default() };
        let entry = rt.model(model)?;
        let dataset = SyntheticDataset::new(entry.num_classes, cfg.seed);
        let pool = ClientPool::build(
            cfg.num_clients,
            cfg.total_samples,
            &dataset,
            cfg.partition(),
            cfg.memory.into(),
            &cfg.fleet_profile()?,
            cfg.seed,
        );
        out.push_str(&format!("\n== {model} (accounting batch {})\n", cfg.memory.accounting_batch));
        let mut rows: Vec<(String, String)> = vec![("Full".into(), "train_full".into())];
        for t in 1..=entry.num_blocks {
            rows.push((format!("{t}st B"), format!("train_t{t}")));
        }
        rows.push(("op".into(), format!("train_op_t{}", entry.num_blocks)));
        for (label, art_name) in rows {
            let art = entry.artifact(&art_name)?;
            let mem = art.participation_mem();
            let bytes = mem.bytes_at(cfg.memory.accounting_batch);
            let pr = pool.participation_rate(&mem);
            let line = format!(
                "  {label:<7} {:>8.1} MB   PR={:>5.1}%   {}",
                bytes as f64 / 1e6,
                pr * 100.0,
                "#".repeat((bytes / 20_000_000) as usize)
            );
            println!("{line}");
            out.push_str(&line);
            out.push('\n');
        }
    }
    save_text("fig6", &out)
}
