//! Quickstart: the smallest end-to-end ProFL run.
//!
//! Loads the AOT artifacts, builds a 12-device fleet with heterogeneous
//! 100-900 MB memory budgets, and runs the full ProFL pipeline —
//! progressive model shrinking, per-block distillation, progressive model
//! growing with effective-movement freezing — then prints the loss curve
//! and final accuracy.
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use profl::methods::{Method, ProFL};
use profl::{artifacts_dir, RunConfig, Runtime};

fn main() -> Result<()> {
    let rt = Runtime::new(&artifacts_dir())?;
    let cfg = RunConfig::smoke("resnet18_w8_c10");
    println!(
        "ProFL quickstart: {} | {} clients, {}/round, budgets {}-{} MB",
        cfg.model_tag, cfg.num_clients, cfg.per_round, cfg.memory.budget_min_mb, cfg.memory.budget_max_mb
    );

    let summary = ProFL::default().run(&rt, &cfg)?;

    println!("\nstage/step  round  loss    train_acc  test_acc  EM      participants");
    for r in &summary.history {
        if r.round % 2 != 0 && r.test_acc.is_nan() {
            continue; // keep the printout short
        }
        println!(
            "{:<7}/{:<3} {:>5}  {:<7.3} {:<9.3} {:<9} {:<7} {}+{}",
            r.stage,
            r.step,
            r.round,
            r.train_loss,
            r.train_acc,
            if r.test_acc.is_nan() { "-".into() } else { format!("{:.3}", r.test_acc) },
            if r.effective_movement.is_nan() { "-".into() } else { format!("{:.3}", r.effective_movement) },
            r.participants,
            r.fallback_participants,
        );
    }
    println!(
        "\nfinal: acc={:.2}%  participation={:.0}%  peak_client_mem={:.1}MB  comm={:.1}MB  rounds={}",
        summary.final_acc * 100.0,
        summary.participation_rate * 100.0,
        summary.peak_client_mem as f64 / 1e6,
        summary.comm_total() as f64 / 1e6,
        summary.rounds
    );
    Ok(())
}
