//! Table 4 — freezing policies: effective movement (ours) vs ParamAware
//! (round budget ∝ block parameter count).
//!
//!   cargo run --release --example table4 -- [--profile ...] [--models ...]

use anyhow::Result;
use profl::harness::{save_text, ExpOpts};
use profl::methods::{FreezePolicy, Method, ProFL};
use profl::Runtime;

fn main() -> Result<()> {
    let opts = ExpOpts::from_env()?;
    let rt = Runtime::new(&profl::artifacts_dir())?;
    let models = opts.models.clone().unwrap_or_else(|| vec!["resnet18_w8_c10".into()]);

    let mut out = String::from("Table 4 — block freezing determination vs ParamAware\n");
    for model in &models {
        for alpha in [None, Some(1.0)] {
            let mut o = ExpOpts { alpha, ..ExpOpts::from_env()? };
            o.alpha = alpha;
            let cfg = o.cfg(model);
            out.push_str(&format!("\n== {model} {}\n", cfg.partition().label()));
            for (label, policy) in
                [("Ours", FreezePolicy::EffectiveMovement), ("ParamAware", FreezePolicy::ParamAware)]
            {
                let m = ProFL { policy, ..Default::default() };
                let s = m.run(&rt, &cfg)?;
                let line =
                    format!("{label:<12} acc={:.1}%  rounds={}", s.final_acc * 100.0, s.rounds);
                println!("{line}");
                out.push_str(&line);
                out.push('\n');
            }
        }
    }
    save_text("table4", &out)
}
