//! Table 3 — ablation: progressive model shrinking ON vs OFF, per-step
//! sub-model accuracy + final global accuracy.
//!
//!   cargo run --release --example table3 -- [--profile ...] [--models ...]

use anyhow::Result;
use profl::harness::{save_text, ExpOpts};
use profl::methods::{Method, ProFL};
use profl::Runtime;

fn main() -> Result<()> {
    let opts = ExpOpts::from_env()?;
    let rt = Runtime::new(&profl::artifacts_dir())?;
    let models = opts.models.clone().unwrap_or_else(|| vec!["resnet18_w8_c10".into()]);

    let mut out = String::from("Table 3 — progressive model shrinking ablation\n");
    for model in &models {
        for alpha in [None, Some(1.0)] {
            let mut o = ExpOpts { alpha, ..ExpOpts::from_env()? };
            o.alpha = alpha;
            let cfg = o.cfg(model);
            out.push_str(&format!("\n== {model} {}\n", cfg.partition().label()));
            for shrink in [true, false] {
                let m = ProFL { shrinking_override: Some(shrink), ..Default::default() };
                let s = m.run(&rt, &cfg)?;
                // Per-step sub-model accuracy: last grow-stage eval per step.
                let steps = s
                    .history
                    .iter()
                    .filter(|r| r.stage == "grow" && !r.test_acc.is_nan())
                    .fold(std::collections::BTreeMap::new(), |mut m, r| {
                        m.insert(r.step, r.test_acc);
                        m
                    });
                let step_str: Vec<String> =
                    steps.iter().map(|(t, a)| format!("step{t}={:.1}%", a * 100.0)).collect();
                let line = format!(
                    "shrinking={:<5}  {}  global={:.1}%",
                    shrink,
                    step_str.join(" "),
                    s.final_acc * 100.0
                );
                println!("{line}");
                out.push_str(&line);
                out.push('\n');
            }
        }
    }
    save_text("table3", &out)
}
