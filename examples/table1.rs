//! Table 1 — ResNet18/ResNet34 × {C10, C100} × {IID, Non-IID(α=1)} across
//! AllSmall / ExclusiveFL / HeteroFL / DepthFL / ProFL: accuracy + PR.
//!
//!   cargo run --release --example table1 -- [--profile smoke|fast|paper]
//!                                            [--models resnet18_w8_c10,...]
//!
//! Paper reference values are printed next to measured ones; the claim
//! being reproduced is the *shape* (who wins, what collapses, PR column),
//! not absolute accuracy (synthetic data, mini widths — DESIGN.md).

use anyhow::Result;
use profl::harness::{fmt_row, paper_reference, save_text, ExpOpts};
use profl::methods::table_methods;
use profl::Runtime;

fn main() -> Result<()> {
    let opts = ExpOpts::from_env()?;
    let rt = Runtime::new(&profl::artifacts_dir())?;
    let models = opts
        .models
        .clone()
        .unwrap_or_else(|| vec!["resnet18_w8_c10".into(), "resnet34_w8_c10".into()]);
    let alphas = [None, Some(1.0)];

    let mut out = String::from("Table 1 — accuracy / participation rate\n");
    for model in &models {
        for alpha in alphas {
            let mut o = ExpOpts { alpha, ..ExpOpts::from_env()? };
            o.alpha = alpha;
            let cfg = o.cfg(model);
            let entry = rt.model(model)?;
            out.push_str(&format!("\n== {model} {}\n", cfg.partition().label()));
            for m in table_methods() {
                let s = m.run(&rt, &cfg)?;
                let mut line = fmt_row(&s);
                if let Some((pa, ppr)) =
                    paper_reference(&entry.family, entry.num_classes, alpha.is_none(), &s.method)
                {
                    line.push_str(&format!("   [paper: {pa:.1}% PR={ppr:.0}%]"));
                }
                println!("{line}");
                out.push_str(&line);
                out.push('\n');
            }
        }
    }
    save_text("table1", &out)
}
