//! Ablation driver: effect of progressive model shrinking on the final
//! model (a focused, faster version of Table 3 over one model).
//!
//!   cargo run --release --example ablation_shrinking -- [--profile smoke]

use anyhow::Result;
use profl::harness::ExpOpts;
use profl::methods::{Method, ProFL};
use profl::Runtime;

fn main() -> Result<()> {
    let opts = ExpOpts::from_env()?;
    let rt = Runtime::new(&profl::artifacts_dir())?;
    let model = opts
        .models
        .clone()
        .and_then(|m| m.first().cloned())
        .unwrap_or_else(|| "resnet18_w8_c10".into());
    let cfg = opts.cfg(&model);
    for shrink in [true, false] {
        let s = ProFL { shrinking_override: Some(shrink), ..Default::default() }.run(&rt, &cfg)?;
        println!(
            "shrinking={shrink:<5} acc={:.2}%  comm={:.1}MB  rounds={}",
            s.final_acc * 100.0,
            s.comm_total() as f64 / 1e6,
            s.rounds
        );
    }
    Ok(())
}
