//! Figures 4 & 5 — effective movement + test accuracy vs round, per step.
//!
//! Runs ProFL and emits a per-round CSV (round, stage, step, EM, test_acc)
//! under artifacts/results/fig4_<model>_<partition>.csv — the exact series
//! the paper plots. The claim to check: EM starts high at each step,
//! decays to a plateau, and the plateau coincides with the accuracy curve
//! flattening (EM is a robust convergence indicator).
//!
//!   cargo run --release --example fig4_5 -- [--profile ...] [--models ...]

use anyhow::Result;
use profl::harness::{results_dir, ExpOpts};
use profl::methods::{Method, ProFL};
use profl::Runtime;

fn main() -> Result<()> {
    let opts = ExpOpts::from_env()?;
    let rt = Runtime::new(&profl::artifacts_dir())?;
    let models = opts.models.clone().unwrap_or_else(|| vec!["resnet18_w8_c10".into()]);

    for model in &models {
        for alpha in [None, Some(1.0)] {
            let mut o = ExpOpts { alpha, ..ExpOpts::from_env()? };
            o.alpha = alpha;
            let cfg = o.cfg(model);
            let label = if alpha.is_none() { "iid" } else { "noniid" };
            let s = ProFL::default().run(&rt, &cfg)?;
            let mut sink = profl::metrics::MetricsSink::new();
            for r in &s.history {
                sink.push(r.clone());
            }
            let path = results_dir().join(format!("fig4_{model}_{label}.csv"));
            sink.write_csv(&path)?;
            // Shape summary: per grow-step first/last EM.
            println!("== {model} {label}");
            for t in 1..=rt.model(model)?.num_blocks {
                let ems: Vec<f64> = s
                    .history
                    .iter()
                    .filter(|r| r.stage == "grow" && r.step == t && !r.effective_movement.is_nan())
                    .map(|r| r.effective_movement)
                    .collect();
                if let (Some(first), Some(last)) = (ems.first(), ems.last()) {
                    println!(
                        "  step{t}: EM {first:.3} -> {last:.3} over {} evals ({})",
                        ems.len(),
                        if first > last { "decaying ✓" } else { "NOT decaying ✗" }
                    );
                }
            }
        }
    }
    Ok(())
}
