//! Mid-round churn sweep — what does device churn cost under each
//! (round policy × churn policy) combination?
//!
//! Drives the discrete-event fleet engine directly (no compiled model
//! artifacts needed, so this runs anywhere — CI smoke mode included):
//! a duty-cycled fleet is sampled once, then every round policy is
//! crossed with every churn policy over the same seeded cohort stream.
//! The table reports merged/aborted/deferred/partial counts, interrupt
//! and resume totals, wasted compute seconds, and total virtual time —
//! the trade-off surface between discarding interrupted work (`abort`),
//! waiting for it (`resume`), and salvaging it at epoch granularity
//! (`checkpoint`).
//!
//!   cargo run --release --example churn_sweep
//!   cargo run --release --example churn_sweep -- --smoke
//!   cargo run --release --example churn_sweep -- --clients 200 --rounds 50 \
//!       --fleet-profile mobile --trace-period 600 --trace-duty 0.6
//!
//! Everything is seeded: same flags ⇒ byte-identical output.

use anyhow::Result;
use profl::cli::Args;
use profl::clients::ClientPool;
use profl::config::{FleetCfg, RunConfig};
use profl::data::{Partition, SyntheticDataset};
use profl::fleet::{ChurnPolicy, ClientWork, FleetEngine, RoundPolicy};
use profl::harness::save_text;
use profl::manifest::MemCoeffs;
use profl::rng::Rng;

/// One cohort member's timings from its sampled device profile; the
/// artifact footprint is a fixed 11 Mparam / 44 MB proxy (ResNet18-ish).
fn works_for(pool: &ClientPool, ids: &[usize], start: f64) -> Vec<ClientWork> {
    let mem = MemCoeffs {
        fixed_bytes: 0,
        per_sample_bytes: 0,
        params_total: 11_000_000,
        params_trainable: 11_000_000,
    };
    let bytes = 44_000_000u64;
    ids.iter()
        .map(|&cid| {
            let c = pool.client(cid);
            let p = &c.profile;
            ClientWork {
                id: cid,
                ready_s: p.trace.next_online(start),
                down_s: p.down_time_s(bytes),
                train_s: p.train_time_s(c.shard.num_samples(), &mem),
                up_s: p.up_time_s(bytes),
                dropout_p: p.dropout_p,
                trace: p.trace,
            }
        })
        .collect()
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let smoke = args.flag("smoke");
    let clients: usize = args.parse_opt("clients")?.unwrap_or(if smoke { 20 } else { 100 });
    let default_cohort = clients.min(if smoke { 8 } else { 30 });
    let per_round: usize = args.parse_opt("per-round")?.unwrap_or(default_cohort);
    let rounds: usize = args.parse_opt("rounds")?.unwrap_or(if smoke { 4 } else { 24 });
    let seed: u64 = args.parse_opt("seed")?.unwrap_or(42);

    // Resolve the fleet through RunConfig so profile names and trace
    // overrides get the same validation as the real CLI. The default
    // trace (240s cycle, 50% duty) is deliberately tight: mobile-tier
    // train times regularly cross the offline edge.
    let fleet = FleetCfg {
        profile: args.get_or("fleet-profile", "mobile").to_string(),
        trace_period_s: args.parse_opt("trace-period")?.or(Some(240.0)),
        trace_duty: args.parse_opt("trace-duty")?.or(Some(0.5)),
        dropout_p: args.parse_opt("dropout")?.or(Some(0.05)),
        ..FleetCfg::default()
    };
    let cfg = RunConfig { seed, fleet, ..Default::default() };
    let profile = cfg.fleet_profile()?;

    let data = SyntheticDataset::new(10, seed);
    let pool = ClientPool::build(
        clients,
        clients * 100,
        &data,
        Partition::Iid,
        cfg.memory.into(),
        &profile,
        seed,
    );

    let buffer_k = (per_round / 2).max(1);
    let policies: [(&str, RoundPolicy, usize, usize); 4] = [
        ("sync", RoundPolicy::Sync, per_round, usize::MAX),
        ("deadline:120", RoundPolicy::Deadline { secs: 120.0 }, per_round, usize::MAX),
        ("over-select:4", RoundPolicy::OverSelect { extra: 4 }, per_round + 4, per_round),
        (
            "async",
            RoundPolicy::Async { buffer_k, max_staleness: 8 },
            per_round,
            usize::MAX,
        ),
    ];
    let churns: [(&str, ChurnPolicy); 4] = [
        ("none", ChurnPolicy::None),
        ("abort", ChurnPolicy::Abort),
        ("resume", ChurnPolicy::Resume),
        ("checkpoint:4", ChurnPolicy::Checkpoint { epochs: 4 }),
    ];

    let mut out = String::from("Mid-round churn sweep — fleet engine only (no artifacts)\n");
    out.push_str(&format!(
        "clients={clients} per_round={per_round} rounds={rounds} fleet={} \
         period={:.0}s duty={:.2} dropout={:.2} buffer_k={buffer_k} seed={seed}\n\n",
        profile.name, profile.period_s, profile.duty, profile.dropout_p,
    ));
    out.push_str(&format!(
        "{:<14} {:<13} {:>6} {:>6} {:>6} {:>7} {:>7} {:>6} {:>6} {:>9} {:>10}\n",
        "policy",
        "churn",
        "merged",
        "late",
        "defer",
        "aborted",
        "partial",
        "intr",
        "resume",
        "wasted_s",
        "sim_time",
    ));

    // One engine serves the whole sweep: `reset()` between combinations
    // restores the fresh-engine state while its per-round scratch
    // (event heap, lookup tables) stays allocated — bit-identical to a
    // new engine per combination (integration-armored in fleet::tests).
    let mut engine = FleetEngine::new();
    for (pname, policy, sample_n, keep) in policies {
        for (cname, churn) in churns {
            // Fresh seeded streams per combination: rows are comparable
            // because every combination sees the same cohort sequence.
            let mut cohort_rng = Rng::new(seed ^ 0xc0_4047);
            let mut fleet_rng = Rng::new(seed ^ 0xf1ee_7c10);
            engine.reset();
            let mut start = 0.0f64;
            let (mut merged, mut late, mut deferred) = (0usize, 0usize, 0usize);
            let mut aborted = 0usize;
            let (mut partial, mut interrupts, mut resumes) = (0usize, 0usize, 0usize);
            let mut wasted = 0.0f64;
            for round in 0..rounds {
                // Sample the cohort excluding clients whose upload is
                // still in flight (mirrors the coordinator).
                let busy: Vec<usize> = engine.inflight().iter().map(|u| u.client).collect();
                let eligible: Vec<usize> =
                    (0..pool.len()).filter(|id| !busy.contains(id)).collect();
                let k = sample_n.min(eligible.len());
                let ids: Vec<usize> = cohort_rng
                    .sample_indices(eligible.len(), k)
                    .into_iter()
                    .map(|i| eligible[i])
                    .collect();
                let works = works_for(&pool, &ids, start);
                let rng = &mut fleet_rng;
                let plan = engine.simulate_round(round, start, &works, policy, keep, churn, rng);
                merged += plan.completers.len();
                late += plan.late_arrivals.len();
                deferred += plan.deferred.len();
                aborted += plan.aborted.len();
                partial += plan.partials.len();
                interrupts += plan.interrupts;
                resumes += plan.resumes;
                wasted += plan.wasted_compute_s;
                start = plan.end_s;
            }
            out.push_str(&format!(
                "{:<14} {:<13} {:>6} {:>6} {:>6} {:>7} {:>7} {:>6} {:>6} {:>9.0} {:>10.0}\n",
                pname, cname, merged, late, deferred, aborted, partial, interrupts, resumes,
                wasted, start,
            ));
        }
    }

    print!("{out}");
    save_text("churn_sweep", &out)?;
    Ok(())
}
