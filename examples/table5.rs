//! Table 5 — per-block parameter quantity and percentage.
//!
//! Printed for both the executed mini models (from the manifest) and the
//! paper-width (64) architecture, which reproduces the paper's numbers
//! exactly (see python/tests/test_models.py::test_table5_*).

use anyhow::Result;
use profl::harness::save_text;
use profl::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::new(&profl::artifacts_dir())?;
    let mut out = String::from("Table 5 — parameter quantity/percentage per block\n");
    // Paper-width reference (exact Table 5 numbers; verified by pytest):
    out.push_str("\npaper width 64 (exact):\n");
    out.push_str("  ResNet18: 0.15M (1.3%) | 0.53M (4.7%) | 2.10M (18.8%) | 8.39M (75.2%)  total 11.2M\n");
    out.push_str("  ResNet34: 0.22M (1.0%) | 1.11M (5.2%) | 6.82M (32.1%) | 13.11M (61.6%) total 21.28M\n");

    for (tag, entry) in &rt.manifest.models {
        if entry.width_ratio != 1.0 {
            continue;
        }
        let total: u64 = entry.block_param_counts.iter().sum();
        let cols: Vec<String> = entry
            .block_param_counts
            .iter()
            .map(|c| format!("{:.3}M ({:.1}%)", *c as f64 / 1e6, *c as f64 / total as f64 * 100.0))
            .collect();
        let line = format!("  {tag:<22} {}  total {:.3}M", cols.join(" | "), total as f64 / 1e6);
        println!("{line}");
        out.push_str(&line);
        out.push('\n');
    }
    save_text("table5", &out)
}
