//! Freezing-policy driver: effective movement vs ParamAware vs no freezing
//! at all (each step runs its full budget). Extends Table 4 with the
//! "never freeze early" control.
//!
//!   cargo run --release --example freezing_policies -- [--profile smoke]

use anyhow::Result;
use profl::harness::ExpOpts;
use profl::methods::{FreezePolicy, Method, ProFL};
use profl::Runtime;

fn main() -> Result<()> {
    let opts = ExpOpts::from_env()?;
    let rt = Runtime::new(&profl::artifacts_dir())?;
    let model = opts
        .models
        .clone()
        .and_then(|m| m.first().cloned())
        .unwrap_or_else(|| "resnet18_w8_c10".into());
    let cfg = opts.cfg(&model);

    // Effective movement (ours)
    let ours = ProFL::default().run(&rt, &cfg)?;
    println!("effective-movement: acc={:.2}% rounds={}", ours.final_acc * 100.0, ours.rounds);

    // ParamAware (Table 4 baseline)
    let pa = ProFL { policy: FreezePolicy::ParamAware, ..Default::default() }.run(&rt, &cfg)?;
    println!("param-aware:        acc={:.2}% rounds={}", pa.final_acc * 100.0, pa.rounds);

    // Never-freeze-early control: disable the detector via a huge phi and
    // patience so every step consumes its whole round budget.
    let mut ctrl_cfg = cfg.clone();
    ctrl_cfg.freeze.phi = 0.0; // slope is never considered flat
    ctrl_cfg.freeze.patience_w = usize::MAX / 2;
    let ctrl = ProFL::default().run(&rt, &ctrl_cfg)?;
    println!("full-budget:        acc={:.2}% rounds={}", ctrl.final_acc * 100.0, ctrl.rounds);
    Ok(())
}
