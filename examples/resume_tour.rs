//! Checkpoint/resume tour — kill a seeded fleet run at every round
//! boundary, resume it from the on-disk checkpoint, and prove the
//! resumed trace is **byte-identical** to the uninterrupted run.
//!
//! Artifact-free: the fleet is synthetic (`ClientPool` over a
//! `SyntheticDataset`, device timings from the named fleet profiles),
//! so this runs anywhere — it is CI's `make resume-smoke` gate.
//!
//! Self-validating — the run aborts (non-zero exit) unless:
//! 1. For every cut `k` in `1..ROUNDS`, and for both eager and lazy
//!    client pools: run `k` rounds, checkpoint through the **real file
//!    codec** (`Checkpoint::write` → `Checkpoint::read`), drop every
//!    live object, rebuild pool/engine/rng from the decoded file, run
//!    the remaining rounds — the merged trace equals the uninterrupted
//!    trace byte for byte (event times and rng states as raw bits).
//! 2. A tampered checkpoint (one flipped payload byte) is rejected by
//!    the state digest with a clean error.
//! 3. A config that hashes differently is rejected by the fingerprint
//!    gate, naming both hashes.
//!
//!   cargo run --release --example resume_tour
//!   cargo run --release --example resume_tour -- --smoke
//!
//! Everything is seeded: same flags ⇒ byte-identical output.
//! Background: docs/CHECKPOINT.md.

use anyhow::{bail, ensure, Result};
use profl::checkpoint::Checkpoint;
use profl::cli::Args;
use profl::clients::ClientPool;
use profl::config::RunConfig;
use profl::data::{Partition, SyntheticDataset};
use profl::fleet::{ChurnPolicy, ClientWork, FleetEngine, RoundPolicy};
use profl::harness::save_text;
use profl::memory::MemoryConfig;
use profl::rng::Rng;
use profl::strategy::{layout_mem, BlockLayout};
use profl::telemetry::{config_sha256, config_value};
use std::fmt::Write as _;

/// ResNet18-scale block parameter counts (the manifest's 4-block split).
const COUNTS: [u64; 4] = [2_000_000, 3_000_000, 3_000_000, 3_200_000];

struct Tour {
    cfg: RunConfig,
    clients: usize,
    per_round: usize,
    rounds: usize,
    seed: u64,
    lazy: bool,
}

impl Tour {
    fn build_pool(&self) -> Result<ClientPool> {
        let data = SyntheticDataset::new(10, self.seed);
        let profile = self.cfg.fleet_profile()?;
        let mem: MemoryConfig = self.cfg.memory.into();
        Ok(if self.lazy {
            ClientPool::build_lazy(
                self.clients,
                self.clients * 60,
                &data,
                Partition::Iid,
                mem,
                &profile,
                self.seed,
                (self.per_round * 2).max(4),
            )
        } else {
            ClientPool::build(
                self.clients,
                self.clients * 60,
                &data,
                Partition::Iid,
                mem,
                &profile,
                self.seed,
            )
        })
    }

    /// One round: pool-rng cohort selection, span timings from the
    /// device profiles, the async discrete-event engine. Returns the
    /// round's trace line (every float as raw bits).
    fn round(
        &self,
        round: usize,
        start: &mut f64,
        pool: &mut ClientPool,
        engine: &mut FleetEngine,
        rng: &mut Rng,
    ) -> String {
        let m = layout_mem(&COUNTS, &BlockLayout::full(COUNTS.len()));
        let sel = pool.select(self.per_round, &m);
        let bytes = 4 * m.params_trainable;
        let works: Vec<ClientWork> = sel
            .trainers
            .iter()
            .map(|&id| {
                let c = pool.client(id);
                let p = &c.profile;
                ClientWork {
                    id,
                    ready_s: p.trace.next_online(*start),
                    down_s: p.down_time_s(bytes),
                    train_s: p.train_time_s(c.shard.num_samples(), &m),
                    up_s: p.up_time_s(bytes),
                    dropout_p: p.dropout_p,
                    trace: p.trace,
                }
            })
            .collect();
        let policy = RoundPolicy::Async { buffer_k: (self.per_round / 2).max(1), max_staleness: 8 };
        let plan = engine.simulate_round(
            round,
            *start,
            &works,
            policy,
            usize::MAX,
            ChurnPolicy::Checkpoint { epochs: 4 },
            rng,
        );
        *start = plan.end_s;
        let mut line = format!(
            "r{round} end=0x{:016x} rng=0x{:016x} completers={:?} late={} inflight={}",
            plan.end_s.to_bits(),
            rng.state(),
            plan.completers,
            plan.late_arrivals.len(),
            engine.inflight().len(),
        );
        let _ = write!(line, " pool={:?}", pool.export_state().select_rng);
        line.push('\n');
        line
    }

    /// The full run, killed at boundary `cut` (`None` = uninterrupted).
    /// Post-cut state lives only in the checkpoint file.
    fn trace(&self, cut: Option<usize>) -> Result<String> {
        let mut out = String::new();
        let mut pool = self.build_pool()?;
        let mut engine = FleetEngine::new();
        let mut rng = Rng::new(self.seed ^ 0xf1ee_7c10);
        let mut start = 0.0;
        let mut round = 0;
        while round < cut.unwrap_or(self.rounds) {
            out.push_str(&self.round(round, &mut start, &mut pool, &mut engine, &mut rng));
            round += 1;
        }
        let Some(cut) = cut else { return Ok(out) };

        // ---- the kill: serialize, drop everything, resurrect ----------
        let ck = Checkpoint {
            crate_version: env!("CARGO_PKG_VERSION").to_string(),
            config_sha256: config_sha256(&self.cfg),
            config_json: config_value(&self.cfg).to_json(),
            round,
            sim_time_s: start,
            prefix_version: 0,
            transitions: Vec::new(),
            fleet_rng: rng.state(),
            threads: 1,
            inflight: engine.inflight().to_vec(),
            pending: Vec::new(),
            params: Vec::new(),
            pool: pool.export_state(),
            records: Vec::new(),
            strategy_name: "ProFL".to_string(),
            strategy_blob: Vec::new(),
            mid: None,
        };
        let path = std::env::temp_dir().join(format!(
            "profl_resume_tour_{}_{}_{cut}.ckpt",
            std::process::id(),
            if self.lazy { "lazy" } else { "eager" },
        ));
        ck.write(&path)?;
        drop(pool);
        drop(engine);
        drop(rng);

        let ck = Checkpoint::read(&path)?;
        std::fs::remove_file(&path).ok();
        // The fingerprint gate must accept the identical config …
        let resolved = ck.resolve_config()?;
        ensure!(config_sha256(&resolved) == ck.config_sha256, "fingerprint drifted");
        // … and the state must reposition every mutable stream.
        let mut pool = self.build_pool()?;
        pool.import_state(&ck.pool)?;
        let mut engine = FleetEngine::new();
        engine.restore_inflight(ck.inflight.clone());
        let mut rng = Rng::from_state(ck.fleet_rng);
        let mut start = ck.sim_time_s;
        for round in ck.round..self.rounds {
            out.push_str(&self.round(round, &mut start, &mut pool, &mut engine, &mut rng));
        }
        Ok(out)
    }
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let smoke = args.flag("smoke");
    let clients: usize = args.parse_opt("clients")?.unwrap_or(if smoke { 12 } else { 40 });
    let rounds: usize = args.parse_opt("rounds")?.unwrap_or(if smoke { 5 } else { 8 });
    let seed: u64 = args.parse_opt("seed")?.unwrap_or(42);
    let mut cfg = RunConfig::smoke("resnet18_w8_c10");
    cfg.fleet.profile = "mobile".to_string();

    let mut out = String::from("Checkpoint/resume tour (docs/CHECKPOINT.md)\n");
    let mut checked = 0usize;

    // ---- 1. resume ≡ uninterrupted, at every boundary, both pools ----
    for lazy in [false, true] {
        let tour = Tour { cfg: cfg.clone(), clients, per_round: 6, rounds, seed, lazy };
        let base = tour.trace(None)?;
        for cut in 1..rounds {
            let resumed = tour.trace(Some(cut))?;
            if resumed != base {
                bail!(
                    "{} pool: resume at boundary {cut} diverged\n--- uninterrupted\n{base}\n--- resumed\n{resumed}",
                    if lazy { "lazy" } else { "eager" },
                );
            }
            checked += 1;
        }
        let _ = writeln!(
            out,
            "{} pool: {} boundaries resumed bit-for-bit over {} rounds",
            if lazy { "lazy" } else { "eager" },
            rounds - 1,
            rounds,
        );
        if !lazy {
            out.push_str(&base);
        }
    }

    // ---- 2. a flipped payload byte must hit the digest wall ----------
    let tour = Tour { cfg: cfg.clone(), clients, per_round: 6, rounds, seed, lazy: false };
    let mut pool = tour.build_pool()?;
    let probe = layout_mem(&COUNTS, &BlockLayout::full(COUNTS.len()));
    let _ = pool.select(6, &probe);
    let ck = Checkpoint {
        crate_version: env!("CARGO_PKG_VERSION").to_string(),
        config_sha256: config_sha256(&cfg),
        config_json: config_value(&cfg).to_json(),
        round: 1,
        sim_time_s: 64.0,
        prefix_version: 0,
        transitions: Vec::new(),
        fleet_rng: 7,
        threads: 1,
        inflight: Vec::new(),
        pending: Vec::new(),
        params: Vec::new(),
        pool: pool.export_state(),
        records: Vec::new(),
        strategy_name: "ProFL".to_string(),
        strategy_blob: Vec::new(),
        mid: None,
    };
    let path = std::env::temp_dir().join(format!("profl_resume_tour_{}_tamper.ckpt", std::process::id()));
    ck.write(&path)?;
    let mut bytes = std::fs::read(&path)?;
    let last = bytes.len() - 1;
    bytes[last] ^= 0x40;
    std::fs::write(&path, &bytes)?;
    let err = match Checkpoint::read(&path) {
        Ok(_) => bail!("tampered checkpoint was accepted"),
        Err(e) => e.to_string(),
    };
    std::fs::remove_file(&path).ok();
    ensure!(err.contains("digest"), "tamper rejection lacks the digest diagnostic: {err}");
    let _ = writeln!(out, "tamper: flipped payload byte rejected ({err})");

    // ---- 3. config drift must be named by the fingerprint gate -------
    let mut drifted = cfg.clone();
    drifted.seed ^= 1;
    let err = match ck.verify_config(&drifted) {
        Ok(()) => bail!("drifted config was accepted"),
        Err(e) => e.to_string(),
    };
    ensure!(
        err.contains("config fingerprint mismatch") && err.contains(&ck.config_sha256),
        "fingerprint rejection must name both hashes: {err}"
    );
    out.push_str("fingerprint: drifted config rejected, both hashes named\n");

    let _ = writeln!(out, "validated: {checked} kill/resume cycles byte-identical");
    print!("{out}");
    save_text("resume_tour", &out)?;
    Ok(())
}
