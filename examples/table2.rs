//! Table 2 — VGG11_bn/VGG16_bn rows (same protocol as Table 1).
//!
//!   cargo run --release --example table2 -- [--profile ...] [--models ...]

use anyhow::Result;
use profl::harness::{fmt_row, paper_reference, save_text, ExpOpts};
use profl::methods::table_methods;
use profl::Runtime;

fn main() -> Result<()> {
    let opts = ExpOpts::from_env()?;
    let rt = Runtime::new(&profl::artifacts_dir())?;
    let models = opts
        .models
        .clone()
        .unwrap_or_else(|| vec!["vgg11_w8_c10".into(), "vgg16_w8_c10".into()]);
    let alphas = [None, Some(1.0)];

    let mut out = String::from("Table 2 — accuracy / participation rate (VGG)\n");
    for model in &models {
        for alpha in alphas {
            let mut o = ExpOpts { alpha, ..ExpOpts::from_env()? };
            o.alpha = alpha;
            let cfg = o.cfg(model);
            let entry = rt.model(model)?;
            out.push_str(&format!("\n== {model} {}\n", cfg.partition().label()));
            for m in table_methods() {
                let s = m.run(&rt, &cfg)?;
                let mut line = fmt_row(&s);
                if let Some((pa, ppr)) =
                    paper_reference(&entry.family, entry.num_classes, alpha.is_none(), &s.method)
                {
                    line.push_str(&format!("   [paper: {pa:.1}% PR={ppr:.0}%]"));
                }
                println!("{line}");
                out.push_str(&line);
                out.push('\n');
            }
        }
    }
    save_text("table2", &out)
}
