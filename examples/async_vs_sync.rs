//! Async vs. sync — time-to-accuracy under FedBuff-style buffering.
//!
//! Runs every Table-1 method four times through the discrete-event
//! fleet simulator on the `mobile` device profile — under `sync` (wait
//! for the slowest device), `deadline` (cut stragglers and discard
//! their work), `async` (close the round at the `buffer_k`-th arrival,
//! keep straggler uploads in flight, merge them on arrival with
//! staleness-discounted weights), and `async+proj` (same, plus
//! `--stale-projection on`: late updates that crossed a freeze/step
//! transition merge their still-trainable suffix instead of being
//! dropped) — and reports simulated time-to-target-accuracy alongside
//! straggler/late-merge/late-drop/projection counts and accuracy per
//! gigabyte. Byte totals are identical between `async` and
//! `async+proj` (a projected merge charges exactly what the drop would
//! have), so any accuracy delta is free per byte — the projection
//! acceptance measure. Everything is seeded: with a fixed seed the
//! output is byte-identical across runs.
//!
//!   cargo run --release --example async_vs_sync
//!   cargo run --release --example async_vs_sync -- --profile smoke \
//!       --buffer-k 5 --staleness-alpha 0.5 --target 0.25 \
//!       --projection-decay 0.5
//!
//! The degenerate configuration (`--buffer-k` = per_round,
//! `--staleness-alpha 0`) reproduces the sync rows bit for bit — see
//! the lib.rs sync-degeneracy guarantee; `docs/SIMULATION.md` has the
//! full determinism contract.

use anyhow::Result;
use profl::cli::Args;
use profl::harness::{save_text, ExpOpts};
use profl::methods::table_methods;
use profl::Runtime;

fn fmt_time(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.1}h", secs / 3600.0)
    } else {
        format!("{:.0}s", secs)
    }
}

fn main() -> Result<()> {
    // One argv parse shared by the harness options and the example's own
    // --target flag.
    let args = Args::parse(std::env::args().skip(1))?;
    let mut opts = ExpOpts::from_args(&args)?;
    // Fleet-stressed defaults (overridable): heterogeneous mobile fleet.
    if opts.fleet_profile.is_none() {
        opts.fleet_profile = Some("mobile".into());
    }
    let target: f64 = args.parse_opt("target")?.unwrap_or(0.3);

    // CI smoke mode runs without compiled artifacts: skip cleanly rather
    // than erroring, so the example still exercises parsing + linking.
    let dir = profl::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("[async_vs_sync] no artifacts at {dir:?} (run `make artifacts`); skipping");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let model = opts
        .models
        .clone()
        .and_then(|m| m.first().cloned())
        .unwrap_or_else(|| "resnet18_w8_c10".into());

    let probe = opts.cfg(&model);
    // Semi-synchronous default: close at half the cohort (a full buffer
    // would just be sync). Overridable with --buffer-k.
    let buffer_k = probe.fleet.buffer_k.unwrap_or((probe.per_round / 2).max(1));

    let mut out = String::from("Async vs sync — FedBuff-style buffering on a heterogeneous fleet\n");
    out.push_str(&format!(
        "model={model} fleet={} deadline={}s buffer_k={} alpha={} max_staleness={} \
         target_acc={:.0}% seed={}\n\n",
        opts.fleet_profile.as_deref().unwrap_or("uniform"),
        probe.fleet.deadline_s,
        buffer_k,
        probe.fleet.staleness_alpha,
        probe.fleet.max_staleness,
        target * 100.0,
        probe.seed,
    ));
    out.push_str(&format!(
        "{:<14} {:<11} {:>6}  {:>9}  {:>9}  {:>6} {:>6} {:>6} {:>6}  {:>8}  {}\n",
        "method",
        "policy",
        "acc",
        "sim_time",
        "t@target",
        "strag",
        "late+",
        "late-",
        "proj",
        "acc/GB",
        "rounds"
    ));

    for m in table_methods() {
        for policy in ["sync", "deadline", "async", "async+proj"] {
            let mut cfg = opts.cfg(&model);
            let is_async = policy.starts_with("async");
            cfg.fleet.round_policy = if is_async { "async".into() } else { policy.into() };
            if is_async {
                cfg.fleet.buffer_k = Some(buffer_k);
            }
            if policy == "async+proj" {
                // The projection row: recover transition-crossed uploads
                // instead of dropping them. Same bytes, more merges.
                cfg.fleet.stale_projection = "on".into();
            }
            let s = m.run(&rt, &cfg)?;
            let acc = if s.final_acc.is_nan() {
                "    NA".to_string()
            } else {
                format!("{:5.1}%", s.final_acc * 100.0)
            };
            let tta = s.time_to_acc(target).map(fmt_time).unwrap_or_else(|| "never".into());
            let (stragglers, _dropouts) = s.fleet_losses();
            let acc_per_gb = if s.final_acc.is_nan() || s.comm_total() == 0 {
                "NA".to_string()
            } else {
                format!("{:.2}", s.final_acc * 100.0 / (s.comm_total() as f64 / 1e9))
            };
            out.push_str(&format!(
                "{:<14} {:<11} {:>6}  {:>9}  {:>9}  {:>6} {:>6} {:>6} {:>6}  {:>8}  {}\n",
                s.method,
                policy,
                acc,
                fmt_time(s.sim_time_s),
                tta,
                stragglers,
                s.late_merges(),
                s.late_drops(),
                s.projected_merges(),
                acc_per_gb,
                s.rounds,
            ));
        }
    }

    print!("{out}");
    save_text("async_vs_sync", &out)?;
    Ok(())
}
