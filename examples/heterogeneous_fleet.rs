//! E2E validation driver: a realistic heterogeneous federation.
//!
//! 100 devices with U[100,900]MB budgets and Dirichlet(1.0) Non-IID data
//! train a block-partitioned ResNet18 with ProFL, end to end through all
//! three layers (Rust coordinator → AOT HLO train steps → PJRT CPU).
//! Logs the loss curve per round and writes the full CSV. This is the
//! run recorded in EXPERIMENTS.md §E2E.
//!
//!   cargo run --release --example heterogeneous_fleet -- [--profile paper]

use anyhow::Result;
use profl::harness::{results_dir, ExpOpts};
use profl::methods::{Method, ProFL};
use profl::Runtime;

fn main() -> Result<()> {
    let opts = ExpOpts::from_env()?;
    let rt = Runtime::new(&profl::artifacts_dir())?;
    let model = opts
        .models
        .clone()
        .and_then(|m| m.first().cloned())
        .unwrap_or_else(|| "resnet18_w8_c10".into());
    let mut cfg = opts.cfg(&model);
    if cfg.dirichlet_alpha.is_none() {
        cfg.dirichlet_alpha = Some(1.0); // paper's Non-IID default
    }

    println!(
        "fleet: {} clients, {}/round, budgets {}-{}MB, {} total samples, {}",
        cfg.num_clients,
        cfg.per_round,
        cfg.memory.budget_min_mb,
        cfg.memory.budget_max_mb,
        cfg.total_samples,
        cfg.partition().label()
    );
    let t0 = std::time::Instant::now();
    let s = ProFL::default().run(&rt, &cfg)?;

    println!("\nloss curve (train loss per round, test acc at evals):");
    for r in &s.history {
        if !r.test_acc.is_nan() {
            println!(
                "  round {:>4} [{}{}] loss={:.4} test_acc={:.3} EM={:.3} clients={}+{}",
                r.round,
                r.stage,
                r.step,
                r.train_loss,
                r.test_acc,
                r.effective_movement,
                r.participants,
                r.fallback_participants
            );
        }
    }
    let mut sink = profl::metrics::MetricsSink::new();
    for r in &s.history {
        sink.push(r.clone());
    }
    let csv = results_dir().join("e2e_heterogeneous_fleet.csv");
    sink.write_csv(&csv)?;
    println!(
        "\nE2E done in {:.0?}: acc={:.2}% PR={:.0}% peak_mem={:.1}MB comm={:.1}MB rounds={} -> {csv:?}",
        t0.elapsed(),
        s.final_acc * 100.0,
        s.participation_rate * 100.0,
        s.peak_client_mem as f64 / 1e6,
        s.comm_total() as f64 / 1e6,
        s.rounds
    );
    Ok(())
}
