//! Fleet dynamics — ProFL vs. baselines under deadline pressure.
//!
//! Runs every Table-1 method twice through the discrete-event fleet
//! simulator — once under the `sync` policy (wait for the slowest
//! device) and once under `deadline` (cut stragglers at the deadline) —
//! on the `mobile` device profile, and reports simulated
//! time-to-target-accuracy alongside the usual accuracy/memory/comm
//! numbers. Everything is seeded: with a fixed seed the output is
//! byte-identical across runs.
//!
//!   cargo run --release --example fleet_dynamics
//!   cargo run --release --example fleet_dynamics -- --profile smoke \
//!       --deadline-s 45 --target 0.25 --fleet-profile mobile

use anyhow::Result;
use profl::cli::Args;
use profl::harness::{save_text, ExpOpts};
use profl::methods::table_methods;
use profl::Runtime;

fn fmt_time(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.1}h", secs / 3600.0)
    } else {
        format!("{:.0}s", secs)
    }
}

fn main() -> Result<()> {
    // One argv parse shared by the harness options and the example's own
    // --target flag.
    let args = Args::parse(std::env::args().skip(1))?;
    let mut opts = ExpOpts::from_args(&args)?;
    // Fleet-stressed defaults (overridable): heterogeneous mobile fleet.
    if opts.fleet_profile.is_none() {
        opts.fleet_profile = Some("mobile".into());
    }
    let target: f64 = args.parse_opt("target")?.unwrap_or(0.3);

    let rt = Runtime::new(&profl::artifacts_dir())?;
    let model = opts
        .models
        .clone()
        .and_then(|m| m.first().cloned())
        .unwrap_or_else(|| "resnet18_w8_c10".into());

    let probe = opts.cfg(&model);
    let mut out = String::from("Fleet dynamics — round policies on a heterogeneous fleet\n");
    out.push_str(&format!(
        "model={model} fleet={} deadline={}s target_acc={:.0}% seed={}\n\n",
        opts.fleet_profile.as_deref().unwrap_or("uniform"),
        probe.fleet.deadline_s,
        target * 100.0,
        probe.seed,
    ));
    out.push_str(&format!(
        "{:<14} {:<10} {:>6}  {:>10}  {:>10}  {:>10} {:>8}  {}\n",
        "method", "policy", "acc", "sim_time", "t@target", "stragglers", "dropouts", "rounds"
    ));

    for m in table_methods() {
        for policy in ["sync", "deadline"] {
            let mut cfg = opts.cfg(&model);
            cfg.fleet.round_policy = policy.into();
            let s = m.run(&rt, &cfg)?;
            let acc = if s.final_acc.is_nan() {
                "    NA".to_string()
            } else {
                format!("{:5.1}%", s.final_acc * 100.0)
            };
            let tta = s.time_to_acc(target).map(fmt_time).unwrap_or_else(|| "never".into());
            let (stragglers, dropouts) = s.fleet_losses();
            out.push_str(&format!(
                "{:<14} {:<10} {:>6}  {:>10}  {:>10}  {:>10} {:>8}  {}\n",
                s.method,
                policy,
                acc,
                fmt_time(s.sim_time_s),
                tta,
                stragglers,
                dropouts,
                s.rounds,
            ));
        }
    }

    print!("{out}");
    save_text("fleet_dynamics", &out)?;
    Ok(())
}
