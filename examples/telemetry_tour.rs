//! Telemetry tour — emit, then validate, a structured-telemetry stream.
//!
//! Drives the discrete-event fleet engine directly (no compiled model
//! artifacts needed, so this runs anywhere — `make telemetry-smoke`
//! included): a duty-cycled *lazy* fleet runs a seeded async round loop
//! while a [`profl::telemetry::Appender`] streams `round.simulate` spans
//! plus fleet/pool gauges to JSONL, and a `manifest.json` provenance
//! record is written beside the stream. The second half re-reads both
//! files and validates the whole contract — every line parses through
//! the crate's own strict JSON parser, carries the required keys, and
//! the sequence numbers strictly increase; the manifest parses and is
//! deterministic modulo its single wall-time field. Any violation exits
//! non-zero, which is what makes this binary a CI smoke gate.
//!
//!   cargo run --release --example telemetry_tour
//!   cargo run --release --example telemetry_tour -- --smoke
//!   cargo run --release --example telemetry_tour -- --out /tmp/tour
//!
//! Everything is seeded: same flags ⇒ identical streams modulo the
//! wall-clock stamps.

use anyhow::{bail, Result};
use profl::cli::Args;
use profl::clients::ClientPool;
use profl::config::{FleetCfg, RunConfig};
use profl::data::{Partition, SyntheticDataset};
use profl::fleet::{ChurnPolicy, ClientWork, FleetEngine, RoundPolicy};
use profl::json::Value;
use profl::manifest::MemCoeffs;
use profl::rng::Rng;
use profl::telemetry::{build_manifest, strip_wall_time, write_manifest, Appender};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// One cohort member's timings from its sampled device profile; the
/// artifact footprint is a fixed 11 Mparam / 44 MB proxy (ResNet18-ish).
/// Takes the pool mutably so lazy clients materialize through the cache
/// (exactly the accounting the `pool.*` gauges observe).
fn works_for(pool: &mut ClientPool, ids: &[usize], start: f64) -> Vec<ClientWork> {
    let mem = MemCoeffs {
        fixed_bytes: 0,
        per_sample_bytes: 0,
        params_total: 11_000_000,
        params_trainable: 11_000_000,
    };
    let bytes = 44_000_000u64;
    ids.iter()
        .map(|&cid| {
            let c = pool.client_mut(cid);
            let p = &c.profile;
            let samples = c.shard.num_samples();
            ClientWork {
                id: cid,
                ready_s: p.trace.next_online(start),
                down_s: p.down_time_s(bytes),
                train_s: p.train_time_s(samples, &mem),
                up_s: p.up_time_s(bytes),
                dropout_p: p.dropout_p,
                trace: p.trace,
            }
        })
        .collect()
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let smoke = args.flag("smoke");
    let clients: usize = args.parse_opt("clients")?.unwrap_or(if smoke { 24 } else { 100 });
    let per_round: usize =
        args.parse_opt("per-round")?.unwrap_or(clients.min(if smoke { 8 } else { 20 }));
    let rounds: usize = args.parse_opt("rounds")?.unwrap_or(if smoke { 6 } else { 24 });
    let seed: u64 = args.parse_opt("seed")?.unwrap_or(42);
    let out_dir = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| std::env::temp_dir().join("profl_telemetry_tour"));
    let stream_path = out_dir.join("telemetry.jsonl");
    let manifest_path = out_dir.join("manifest.json");

    // Resolve the fleet through RunConfig so profile names get the same
    // validation as the real CLI; the config also feeds the manifest, so
    // the provenance record describes exactly what ran.
    let fleet = FleetCfg {
        profile: "mobile".to_string(),
        trace_period_s: Some(240.0),
        trace_duty: Some(0.5),
        dropout_p: Some(0.05),
        lazy_pool: true,
        ..FleetCfg::default()
    };
    let mut cfg = RunConfig { seed, fleet, ..Default::default() };
    cfg.per_round = per_round;
    cfg.num_clients = clients;
    cfg.telemetry_jsonl = Some(stream_path.display().to_string());
    let profile = cfg.fleet_profile()?;

    let data = SyntheticDataset::new(10, seed);
    // Deliberately tight resident cap: the tour wants cache evictions in
    // its gauges, not just cold-start misses.
    let cap = (per_round + per_round / 2).max(4);
    let mut pool = ClientPool::build_lazy(
        clients,
        clients * 100,
        &data,
        Partition::Iid,
        cfg.memory.into(),
        &profile,
        seed,
        cap,
    );

    // ---- emit: seeded async round loop, one span + gauges per round ----
    let mut tel = Appender::create(&stream_path)?;
    let policy = RoundPolicy::Async { buffer_k: (per_round / 2).max(1), max_staleness: 8 };
    let churn = ChurnPolicy::Checkpoint { epochs: 4 };
    let mut cohort_rng = Rng::new(seed ^ 0xc0_4047);
    let mut fleet_rng = Rng::new(seed ^ 0xf1ee_7c10);
    let mut engine = FleetEngine::new();
    let mut start = 0.0f64;
    for round in 0..rounds {
        let busy: Vec<usize> = engine.inflight().iter().map(|u| u.client).collect();
        let eligible: Vec<usize> = (0..pool.len()).filter(|id| !busy.contains(id)).collect();
        let k = per_round.min(eligible.len());
        let ids: Vec<usize> =
            cohort_rng.sample_indices(eligible.len(), k).into_iter().map(|i| eligible[i]).collect();
        let works = works_for(&mut pool, &ids, start);
        let t0 = std::time::Instant::now();
        let plan =
            engine.simulate_round(round, start, &works, policy, usize::MAX, churn, &mut fleet_rng);
        start = plan.end_s;
        tel.span(
            "round.simulate",
            round,
            start,
            t0.elapsed().as_secs_f64(),
            &[
                ("cohort", Value::Num(works.len() as f64)),
                ("completers", Value::Num(plan.completers.len() as f64)),
                ("late_arrivals", Value::Num(plan.late_arrivals.len() as f64)),
            ],
        );
        tel.gauge("fleet.queue_peak", round, start, engine.last_queue_peak() as f64, &[]);
        tel.gauge("fleet.inflight_len", round, start, engine.inflight().len() as f64, &[]);
        let stats = pool.stats();
        tel.gauge("pool.cache_hits", round, start, stats.hits as f64, &[]);
        tel.gauge("pool.cache_misses", round, start, stats.misses as f64, &[]);
        tel.gauge("pool.cache_evictions", round, start, stats.evictions as f64, &[]);
        tel.gauge("pool.peak_materialized", round, start, stats.peak_materialized as f64, &[]);
    }
    let emitted = tel.lines();
    let dropped = tel.dropped_writes();
    drop(tel); // flush

    let argv: Vec<String> = std::env::args().collect();
    let manifest = build_manifest(&cfg, &argv, None, Some((&stream_path, emitted)));
    write_manifest(&manifest_path, &manifest)?;

    // ---- validate: the stream and manifest must honour the contract ----
    if dropped != 0 {
        bail!("telemetry stream dropped {dropped} writes");
    }
    let text = std::fs::read_to_string(&stream_path)?;
    let mut by_name: BTreeMap<String, usize> = BTreeMap::new();
    let mut prev_seq: Option<u64> = None;
    let mut n = 0u64;
    for line in text.lines() {
        let v = Value::parse(line)?;
        for key in ["seq", "wall_ms", "sim_s", "round", "kind", "name"] {
            if v.get(key).is_err() {
                bail!("required key `{key}` missing in line: {line}");
            }
        }
        let seq = v.get("seq")?.as_u64()?;
        if let Some(p) = prev_seq {
            if seq <= p {
                bail!("seq not strictly increasing: {p} then {seq}");
            }
        }
        prev_seq = Some(seq);
        let kind = v.get("kind")?.as_str()?;
        match kind {
            "span" => {
                v.get("dur_s")?;
            }
            "counter" | "gauge" => {
                v.get("value")?;
            }
            other => bail!("unknown event kind `{other}`"),
        }
        *by_name.entry(v.get("name")?.as_str()?.to_string()).or_insert(0) += 1;
        n += 1;
    }
    if n != emitted {
        bail!("stream has {n} lines, appender reported {emitted}");
    }
    if n == 0 {
        bail!("empty telemetry stream");
    }

    let mtext = std::fs::read_to_string(&manifest_path)?;
    let mv = Value::parse(mtext.trim())?;
    if mv.get("config_sha256")?.as_str()?.len() != 64 {
        bail!("manifest config_sha256 is not a sha256 hex digest");
    }
    if mv.get("telemetry")?.get("lines")?.as_u64()? != emitted {
        bail!("manifest line count disagrees with the stream");
    }
    // Reproducibility: a second manifest from the same config differs
    // only by the wall-time field.
    let manifest2 = build_manifest(&cfg, &argv, None, Some((&stream_path, emitted)));
    if strip_wall_time(&manifest).to_json() != strip_wall_time(&manifest2).to_json() {
        bail!("manifest is not deterministic modulo wall time");
    }

    // ---- report ---------------------------------------------------------
    println!("telemetry tour — stream + manifest validated");
    println!(
        "clients={clients} per_round={per_round} rounds={rounds} seed={seed} cap={cap} \
         policy=async churn=checkpoint:4"
    );
    println!("stream:   {} ({n} events)", stream_path.display());
    println!("manifest: {}", manifest_path.display());
    println!("events by name:");
    for (name, count) in &by_name {
        println!("  {name:<24} {count:>5}");
    }
    if let Some(first) = text.lines().next() {
        println!("sample line:\n  {first}");
    }
    Ok(())
}
