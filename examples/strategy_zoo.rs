//! Memory-wall strategy zoo — every [`MemoryStrategy`] head-to-head
//! over the same seeded fleets: accuracy proxy × peak client memory ×
//! time-to-accuracy × communication, across fleet profiles, round
//! policies, and churn.
//!
//! Artifact-free: schedules are enumerated against a synthetic
//! [`ModelView`] (ResNet18-scale block parameter counts), footprints
//! come from the pure `layout_mem` model, and rounds are driven through
//! the discrete-event fleet engine — so this runs anywhere, CI smoke
//! mode included.
//!
//! Self-validating — the run aborts (non-zero exit) unless:
//! 1. ProFL and ParamAware enumerated via the [`MemoryStrategy`] trait
//!    reproduce an *inline transcription of the legacy schedule* phase
//!    for phase (stage, step, layout, artifacts, budgets, learning
//!    rates) — the refactor's schedule-level degeneracy contract.
//! 2. No phase's footprint exceeds full-model training, and every
//!    client the memory filter admits also fits the dispatched layout
//!    statically (`can_train ⇒ fits_static`).
//! 3. LayerFreeze's per-client depth caps fit each device's budget.
//!
//!   cargo run --release --example strategy_zoo
//!   cargo run --release --example strategy_zoo -- --smoke
//!   cargo run --release --example strategy_zoo -- --clients 200 --seed 7
//!
//! Everything is seeded: same flags ⇒ byte-identical output. The
//! "accuracy" column is a *coverage proxy* (how much of the model
//! trained, for how many rounds), not a learned accuracy — the zoo
//! compares schedules, not gradients; see docs/STRATEGIES.md.

use anyhow::{bail, Result};
use profl::cli::Args;
use profl::clients::ClientPool;
use profl::config::{FleetCfg, RunConfig};
use profl::data::{Partition, SyntheticDataset};
use profl::fleet::{ChurnPolicy, ClientWork, FleetEngine, RoundPolicy};
use profl::harness::save_text;
use profl::memory::{can_train, MemoryConfig};
use profl::rng::Rng;
use profl::strategy::{
    depth_cap, layout_mem, BlockLayout, Elastic, FreezePolicy, LayerFreeze, MemoryStrategy,
    ModelView, Phase, Progressive, StepFeedback,
};

/// ResNet18-scale block parameter counts (the manifest's 4-block split).
const COUNTS: [u64; 4] = [2_000_000, 3_000_000, 3_000_000, 3_200_000];

/// Rounds an EM-gated phase takes to "converge" in the synthetic
/// feedback script (deterministic stand-in for the freeze detector).
const CONV_ROUNDS: usize = 3;

/// Enumerate a strategy's full schedule under the synthetic feedback
/// script: EM-gated train phases converge after [`CONV_ROUNDS`], others
/// run out their budget, distillation always completes.
fn enumerate(s: &mut dyn MemoryStrategy, view: &ModelView, cfg: &RunConfig) -> Vec<Phase> {
    let mut phases = Vec::new();
    let mut last: Option<StepFeedback> = None;
    while let Some(p) = s.next_phase(view, cfg, last.as_ref()) {
        last = match &p {
            Phase::Transition => None,
            Phase::Train(t) => {
                let used = if t.em_gated { CONV_ROUNDS.min(t.max_rounds) } else { t.max_rounds };
                Some(StepFeedback { rounds_used: used, froze: t.em_gated && used < t.max_rounds })
            }
            Phase::Distill(d) => Some(StepFeedback { rounds_used: d.rounds, froze: false }),
        };
        phases.push(p);
    }
    phases
}

/// One expected phase of the legacy ProFL schedule (independent
/// transcription of the pre-refactor `methods::profl` loops).
#[derive(Debug, PartialEq)]
enum Expect {
    Transition,
    Train { stage: &'static str, step: usize, max_rounds: usize, lr: f32 },
    Distill { step: usize, rounds: usize },
}

/// Inline transcription of the legacy schedule arithmetic: shrink T→2
/// (train + Map distill per step), then grow 1→T, sharing one
/// `2 × max_rounds_total` budget, with per-step grow floors and lr
/// decay. Kept deliberately separate from `strategy::progressive` so a
/// port bug cannot hide in shared code.
fn legacy_schedule(cfg: &RunConfig, policy: FreezePolicy, num_blocks: usize) -> Vec<Expect> {
    let param_aware = |t: usize| -> usize {
        let total: u64 = COUNTS.iter().sum();
        let share = COUNTS[t - 1] as f64 / total as f64;
        let budget = cfg.max_rounds_per_step * COUNTS.len();
        ((budget as f64 * share) as usize).max(4)
    };
    let step_max = |t: usize, budget: usize| -> usize {
        match policy {
            FreezePolicy::EffectiveMovement => cfg.max_rounds_per_step.min(budget),
            FreezePolicy::ParamAware => param_aware(t).min(budget),
        }
    };
    // ParamAware phases never EM-gate, so they always run their budget
    // out; EM phases "converge" per the synthetic feedback script.
    let used = |max: usize| -> usize {
        match policy {
            FreezePolicy::EffectiveMovement => CONV_ROUNDS.min(max),
            FreezePolicy::ParamAware => max,
        }
    };
    let mut out = Vec::new();
    let mut lr = cfg.lr;
    let mut remaining = cfg.max_rounds_total * 2;
    if cfg.shrinking && num_blocks >= 2 {
        for t in (2..=num_blocks).rev() {
            out.push(Expect::Transition);
            let max = step_max(t, remaining);
            out.push(Expect::Train { stage: "shrink", step: t, max_rounds: max, lr });
            remaining = remaining.saturating_sub(used(max));
            out.push(Expect::Distill { step: t, rounds: cfg.distill_rounds });
            remaining = remaining.saturating_sub(cfg.distill_rounds);
        }
    }
    for t in 1..=num_blocks {
        out.push(Expect::Transition);
        let budget = remaining.max(cfg.min_rounds_per_step);
        let max = step_max(t, budget);
        out.push(Expect::Train { stage: "grow", step: t, max_rounds: max, lr });
        remaining = remaining.saturating_sub(used(max));
        lr *= cfg.lr_step_decay;
    }
    out
}

/// Assert the trait-enumerated schedule matches the legacy
/// transcription phase for phase (the degeneracy proof).
fn assert_degeneracy(cfg: &RunConfig, policy: FreezePolicy) -> Result<()> {
    let view = ModelView::synthetic(&COUNTS);
    let mut s = Progressive::new(policy);
    let got = enumerate(&mut s, &view, cfg);
    let expect = legacy_schedule(cfg, policy, view.num_blocks);
    if got.len() != expect.len() {
        bail!("{policy:?}: {} phases via trait, {} via legacy", got.len(), expect.len());
    }
    for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
        let ok = match (g, e) {
            (Phase::Transition, Expect::Transition) => true,
            (Phase::Train(t), Expect::Train { stage, step, max_rounds, lr }) => {
                t.stage == *stage
                    && t.step == *step
                    && t.max_rounds == *max_rounds
                    && t.lr == *lr
                    && t.layout == BlockLayout { frozen: step - 1, depth: *step }
                    && t.train_artifact == format!("train_t{step}")
                    && t.fallback_artifact.as_deref() == Some(&format!("train_op_t{step}")[..])
                    && t.eval_artifact == format!("eval_t{step}")
            }
            (Phase::Distill(d), Expect::Distill { step, rounds }) => {
                d.step == *step && d.rounds == *rounds && d.artifact == format!("distill_t{step}")
            }
            _ => false,
        };
        if !ok {
            bail!("{policy:?}: phase {i} diverged — trait {g:?} vs legacy {e:?}");
        }
    }
    Ok(())
}

/// Per-round cohort timings for a phase footprint: download/upload move
/// the trainable parameters, training cost scales with the footprint.
fn works_for(pool: &ClientPool, ids: &[(usize, BlockLayout)], start: f64) -> Vec<ClientWork> {
    ids.iter()
        .map(|&(cid, layout)| {
            let m = layout_mem(&COUNTS, &layout);
            let bytes = 4 * m.params_trainable;
            let c = pool.client(cid);
            let p = &c.profile;
            ClientWork {
                id: cid,
                ready_s: p.trace.next_online(start),
                down_s: p.down_time_s(bytes),
                train_s: p.train_time_s(c.shard.num_samples(), &m),
                up_s: p.up_time_s(bytes),
                dropout_p: p.dropout_p,
                trace: p.trace,
            }
        })
        .collect()
}

/// One head-to-head row.
struct RowOut {
    acc: f64,
    peak_mem_mb: f64,
    time_to_acc: Option<f64>,
    comm_mb: f64,
    sim_s: f64,
    participants: usize,
}

#[allow(clippy::too_many_arguments)]
fn run_combo(
    strategy: &mut dyn MemoryStrategy,
    cfg: &RunConfig,
    pool: &mut ClientPool,
    engine: &mut FleetEngine,
    policy: RoundPolicy,
    keep: usize,
    churn: ChurnPolicy,
    per_round: usize,
    seed: u64,
) -> Result<RowOut> {
    let view = ModelView::synthetic(&COUNTS);
    let mcfg: MemoryConfig = cfg.memory.into();
    let batch = mcfg.accounting_batch;
    let total_params: u64 = COUNTS.iter().sum();
    let full_bytes = layout_mem(&COUNTS, &BlockLayout::full(COUNTS.len())).bytes_at(batch);
    let layerfreeze = strategy.name() == "LayerFreeze";
    let phases = enumerate(strategy, &view, cfg);

    let mut cohort_rng = Rng::new(seed ^ 0xc0_4047);
    let mut fleet_rng = Rng::new(seed ^ 0xf1ee_7c10);
    engine.reset();
    let mut start = 0.0f64;
    let mut round = 0usize;
    // Coverage proxy: block-rounds of training, parameter-weighted.
    let mut coverage = vec![0.0f64; COUNTS.len()];
    let need_rounds = 6.0;
    let acc_of = |cov: &[f64]| -> f64 {
        let trained: f64 = cov
            .iter()
            .zip(&COUNTS)
            .map(|(c, &p)| (c / need_rounds).min(1.0) * p as f64)
            .sum();
        0.40 + 0.50 * trained / total_params as f64
    };
    let target_acc = 0.75;
    let mut out = RowOut {
        acc: 0.0,
        peak_mem_mb: 0.0,
        time_to_acc: None,
        comm_mb: 0.0,
        sim_s: 0.0,
        participants: 0,
    };

    for phase in &phases {
        let p = match phase {
            Phase::Train(p) => p,
            // Transitions are instantaneous here; distillation rounds
            // move output-module-sized tensors only and do not touch
            // coverage — the zoo compares *training* schedules.
            _ => continue,
        };
        let rounds = if p.em_gated { CONV_ROUNDS.min(p.max_rounds) } else { p.max_rounds };
        for _ in 0..rounds {
            let busy: Vec<usize> = engine.inflight().iter().map(|u| u.client).collect();
            let eligible: Vec<usize> = (0..pool.len()).filter(|id| !busy.contains(id)).collect();
            let k = per_round.min(eligible.len());
            let ids: Vec<usize> = cohort_rng
                .sample_indices(eligible.len(), k)
                .into_iter()
                .map(|i| eligible[i])
                .collect();
            // Memory filter: the phase layout for window strategies; a
            // per-device depth cap for layerfreeze (its defining move).
            let mut admitted: Vec<(usize, BlockLayout)> = Vec::new();
            for id in ids {
                let layout = if layerfreeze {
                    let budget = pool.client(id).memory.budget;
                    match depth_cap(&COUNTS, p.layout.frozen, budget, batch) {
                        Some(l) => l,
                        None => continue,
                    }
                } else {
                    p.layout
                };
                let m = layout_mem(&COUNTS, &layout);
                let avail = pool.client_mut(id).memory.available(&mcfg);
                if !can_train(avail, &mcfg, &m) {
                    continue;
                }
                // Self-validation 2+3: dispatch respects the static fit
                // and never out-costs full-model training.
                if !pool.client(id).memory.fits_static(&mcfg, &m) {
                    bail!("{}: client {id} admitted beyond its static budget", strategy.name());
                }
                let bytes = m.bytes_at(batch);
                if bytes > full_bytes {
                    bail!("{}: layout {layout:?} out-costs full-model training", strategy.name());
                }
                out.peak_mem_mb = out.peak_mem_mb.max(bytes as f64 / 1e6);
                admitted.push((id, layout));
            }
            let works = works_for(pool, &admitted, start);
            let plan =
                engine.simulate_round(round, start, &works, policy, keep, churn, &mut fleet_rng);
            let merged = plan.completers.len() + plan.late_arrivals.len();
            out.participants += merged;
            for &(_, layout) in &admitted {
                let m = layout_mem(&COUNTS, &layout);
                out.comm_mb += 2.0 * (4 * m.params_trainable) as f64 / 1e6;
            }
            if merged > 0 {
                // The phase window is the fleet-level coverage envelope
                // (layerfreeze clients may train shallower than it).
                let w = (merged as f64 / works.len().max(1) as f64).min(1.0);
                for c in coverage[p.layout.frozen..p.layout.depth].iter_mut() {
                    *c += w;
                }
            }
            start = plan.end_s;
            round += 1;
            let acc_now = acc_of(&coverage);
            if out.time_to_acc.is_none() && acc_now >= target_acc {
                out.time_to_acc = Some(start);
            }
        }
    }
    out.acc = acc_of(&coverage);
    out.sim_s = start;
    Ok(out)
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let smoke = args.flag("smoke");
    let clients: usize = args.parse_opt("clients")?.unwrap_or(if smoke { 20 } else { 80 });
    let per_round: usize = args.parse_opt("per-round")?.unwrap_or(if smoke { 6 } else { 16 });
    let seed: u64 = args.parse_opt("seed")?.unwrap_or(42);

    // The smoke budget profile drives schedule enumeration in both
    // modes — the zoo compares schedule *shapes*, and the shapes are
    // profile-independent; --smoke only shrinks the fleet.
    let cfg = RunConfig::smoke("resnet18_w8_c10");

    // ---- 1. Degeneracy: ProFL-via-trait ≡ legacy schedule ------------
    assert_degeneracy(&cfg, FreezePolicy::EffectiveMovement)?;
    assert_degeneracy(&cfg, FreezePolicy::ParamAware)?;
    let mut noshrink = cfg.clone();
    noshrink.shrinking = false;
    assert_degeneracy(&noshrink, FreezePolicy::EffectiveMovement)?;

    let mut out = String::from("Memory-wall strategy zoo — schedule-level head-to-head\n");
    out.push_str("degeneracy: ProFL/ParamAware via MemoryStrategy ≡ legacy schedule OK\n");
    out.push_str(&format!(
        "clients={clients} per_round={per_round} seed={seed} \
         (accuracy is a coverage proxy; see docs/STRATEGIES.md)\n\n"
    ));

    // ---- 2. Head-to-head: strategies × (fleet, policy, churn) --------
    let combos: [(&str, &str, RoundPolicy, usize, ChurnPolicy); 3] = [
        ("uniform", "sync", RoundPolicy::Sync, usize::MAX, ChurnPolicy::None),
        (
            "mobile",
            "async",
            RoundPolicy::Async { buffer_k: (per_round / 2).max(1), max_staleness: 8 },
            usize::MAX,
            ChurnPolicy::Checkpoint { epochs: 4 },
        ),
        (
            "datacenter",
            "deadline:120",
            RoundPolicy::Deadline { secs: 120.0 },
            usize::MAX,
            ChurnPolicy::Abort,
        ),
    ];
    out.push_str(&format!(
        "{:<12} {:<11} {:<13} {:<13} {:>6} {:>9} {:>9} {:>9} {:>9} {:>7}\n",
        "strategy", "fleet", "policy", "churn", "acc*", "peak_MB", "t2acc_s", "comm_MB", "sim_s",
        "merged",
    ));

    let mut engine = FleetEngine::new();
    for (fleet_name, pname, policy, keep, churn) in combos {
        let mut combo_cfg = cfg.clone();
        combo_cfg.fleet = FleetCfg { profile: fleet_name.to_string(), ..FleetCfg::default() };
        let profile = combo_cfg.fleet_profile()?;
        let data = SyntheticDataset::new(10, seed);
        let mut strategies: Vec<Box<dyn MemoryStrategy>> = vec![
            Box::new(Progressive::new(FreezePolicy::EffectiveMovement)),
            Box::new(Progressive::new(FreezePolicy::ParamAware)),
            Box::new(LayerFreeze::default()),
            Box::new(Elastic::default()),
        ];
        for s in strategies.iter_mut() {
            let name = s.name();
            // Fresh pool per row: device contention streams are stateful,
            // and every strategy must see the identical fleet.
            let mut pool = ClientPool::build(
                clients,
                clients * 60,
                &data,
                Partition::Iid,
                combo_cfg.memory.into(),
                &profile,
                seed,
            );
            let row = run_combo(
                s.as_mut(),
                &combo_cfg,
                &mut pool,
                &mut engine,
                policy,
                keep,
                churn,
                per_round,
                seed,
            )?;
            let t2acc = match row.time_to_acc {
                Some(t) => format!("{t:.0}"),
                None => "-".to_string(),
            };
            out.push_str(&format!(
                "{:<12} {:<11} {:<13} {:<13} {:>5.1}% {:>9.1} {:>9} {:>9.1} {:>9.0} {:>7}\n",
                name,
                fleet_name,
                pname,
                match churn {
                    ChurnPolicy::None => "none",
                    ChurnPolicy::Abort => "abort",
                    ChurnPolicy::Resume => "resume",
                    ChurnPolicy::Checkpoint { .. } => "checkpoint:4",
                },
                row.acc * 100.0,
                row.peak_mem_mb,
                t2acc,
                row.comm_mb,
                row.sim_s,
                row.participants,
            ));
        }
    }

    out.push_str("\nvalidated: footprints ≤ full-model; dispatch respects fits_static; \
                  layerfreeze per-client depth caps fit\n");
    print!("{out}");
    save_text("strategy_zoo", &out)?;
    Ok(())
}
