//! §4.6 — communication-cost accounting.
//!
//! Compares ProFL (with and without the shrinking stage) against the
//! "ideal" full-model FedAvg baseline at matched accuracy: communicated
//! bytes and peak client memory. Paper claims (ResNet18/C10/IID): +59.4%
//! comm for −53.3% peak memory; dropping shrinking saves 58.1% comm.
//!
//!   cargo run --release --example comm_cost -- [--profile ...]

use anyhow::Result;
use profl::harness::{save_text, ExpOpts};
use profl::methods::{Method, ProFL};
use profl::Runtime;

fn main() -> Result<()> {
    let opts = ExpOpts::from_env()?;
    let rt = Runtime::new(&profl::artifacts_dir())?;
    let model = opts
        .models
        .clone()
        .and_then(|m| m.first().cloned())
        .unwrap_or_else(|| "resnet18_w8_c10".into());
    let cfg = opts.cfg(&model);

    // Ideal baseline: full-model FedAvg with no memory constraints
    // (every sampled client trains the full model).
    let mut ideal_cfg = cfg.clone();
    ideal_cfg.memory.budget_min_mb = 100_000; // effectively infinite
    ideal_cfg.memory.budget_max_mb = 100_001;
    let ideal = profl::methods::ExclusiveFL.run(&rt, &ideal_cfg)?;

    let with_shrink = ProFL { shrinking_override: Some(true), ..Default::default() }.run(&rt, &cfg)?;
    let no_shrink = ProFL { shrinking_override: Some(false), ..Default::default() }.run(&rt, &cfg)?;

    let mut out = String::from("§4.6 — communication cost vs ideal full-model training\n\n");
    for (name, s) in
        [("Ideal(full)", &ideal), ("ProFL", &with_shrink), ("ProFL-noshrink", &no_shrink)]
    {
        let line = format!(
            "{name:<15} acc={:>5.1}%  comm={:>8.1}MB  peak_mem={:>7.1}MB  rounds={}",
            s.final_acc * 100.0,
            s.comm_total() as f64 / 1e6,
            s.peak_client_mem as f64 / 1e6,
            s.rounds
        );
        println!("{line}");
        out.push_str(&line);
        out.push('\n');
    }
    let comm_delta = with_shrink.comm_total() as f64 / ideal.comm_total() as f64 - 1.0;
    let mem_delta = 1.0 - with_shrink.peak_client_mem as f64 / ideal.peak_client_mem as f64;
    let shrink_saving = 1.0 - no_shrink.comm_total() as f64 / with_shrink.comm_total() as f64;
    let summary = format!(
        "\nProFL vs ideal: comm {:+.1}%  peak memory −{:.1}%   (paper: +59.4%, −53.3%)\n\
         dropping shrinking saves {:.1}% comm                (paper: 58.1%)\n",
        comm_delta * 100.0,
        mem_delta * 100.0,
        shrink_saving * 100.0
    );
    println!("{summary}");
    out.push_str(&summary);
    save_text("comm_cost", &out)
}
