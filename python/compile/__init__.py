"""Build-time-only Python: L1 Pallas kernels + L2 JAX model graphs + the
AOT pipeline that lowers them to HLO-text artifacts for the Rust runtime.
Never imported on the request path."""
