"""AOT compiler: lower every ProFL artifact to HLO text + manifest.json.

This is the *only* place Python runs — once, at build time (`make
artifacts`). The Rust coordinator is self-contained afterwards: it reads
``artifacts/manifest.json``, loads each ``*.hlo.txt`` through
``HloModuleProto::from_text_file``, compiles on the PJRT CPU client and
executes on the round path.

Interchange is HLO **text**, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids.

Artifact inventory per model tag (family × width × classes):

  train_t{t}       step-t sub-model SGD (ProFL grow & shrink; §3.1/3.2)
  train_op_t{t}    output-module-only SGD (clients below every block; §4.1)
  distill_t{t}     block→surrogate Map step (§3.2), t = 2..T
  eval_t{t}        step-t sub-model test pass (t = T ⇒ full model)
  train_full       end-to-end SGD (ExclusiveFL; HeteroFL/AllSmall on
                   width-ratio variant tags)
  depthfl_train_d{d}, depthfl_eval   DepthFL baseline

Usage:
  python -m compile.aot --out-dir ../artifacts                # default set
  python -m compile.aot --set full                            # all tables
  python -m compile.aot --kernels pallas --models resnet18:8:10
  python -m compile.aot --report                              # L1 perf report
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import graphs, memory
from .graphs import InSpec
from .kernels import conv as kconv
from .kernels.matmul import mxu_utilization, vmem_bytes
from .models import ModelCfg, ModelDef, build, block_param_counts

# Static execution geometry, shared with Rust via the manifest.
# Sized for the single-core CPU PJRT testbed: one train call = SCAN_STEPS
# SGD steps over TRAIN_BATCH samples (~0.2s on one core for the mini
# ResNet18); the paper-twin memory accounting uses its own batch (128).
TRAIN_BATCH = 16
SCAN_STEPS = 2  # local batches per executable call (one "epoch chunk")
EVAL_BATCH = 128

# Width ratios offered to HeteroFL / AllSmall (HeteroFL's 4 complexity
# levels; AllSmall uses whichever its min-memory client affords).
WIDTH_RATIOS = (0.5, 0.25, 0.125)

DEFAULT_SET = ["resnet18:8:10"]
FULL_SET = [
    "resnet18:8:10",
    "resnet18:8:100",
    "resnet34:8:10",
    "resnet34:8:100",
    "vgg11:8:10",
    "vgg11:8:100",
    "vgg16:8:10",
    "vgg16:8:100",
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shaped(spec: InSpec, names: list[str]):
    return [jnp.zeros(spec.shapes[n], jnp.float32) for n in names]


def _train_example_args(spec: InSpec):
    xs = jnp.zeros((SCAN_STEPS, TRAIN_BATCH, 32, 32, 3), jnp.float32)
    ys = jnp.zeros((SCAN_STEPS, TRAIN_BATCH), jnp.int32)
    lr = jnp.float32(0.0)
    return _shaped(spec, spec.trainable) + _shaped(spec, spec.frozen) + [xs, ys, lr]


def _distill_example_args(spec: InSpec):
    xs = jnp.zeros((SCAN_STEPS, TRAIN_BATCH, 32, 32, 3), jnp.float32)
    lr = jnp.float32(0.0)
    return _shaped(spec, spec.trainable) + _shaped(spec, spec.frozen) + [xs, lr]


def _eval_example_args(spec: InSpec):
    x = jnp.zeros((EVAL_BATCH, 32, 32, 3), jnp.float32)
    y = jnp.zeros((EVAL_BATCH,), jnp.int32)
    return _shaped(spec, spec.frozen) + [x, y]


def _input_entries(spec: InSpec, kind: str) -> list[dict]:
    ins = []
    for n in spec.trainable:
        ins.append({"name": n, "role": "trainable", "shape": list(spec.shapes[n])})
    for n in spec.frozen:
        role = "param" if kind.startswith("eval") else "frozen"
        ins.append({"name": n, "role": role, "shape": list(spec.shapes[n])})
    if kind == "train":
        ins.append({"name": "xs", "role": "data_x", "shape": [SCAN_STEPS, TRAIN_BATCH, 32, 32, 3]})
        ins.append({"name": "ys", "role": "data_y", "shape": [SCAN_STEPS, TRAIN_BATCH]})
        ins.append({"name": "lr", "role": "lr", "shape": []})
    elif kind == "distill":
        ins.append({"name": "xs", "role": "data_x", "shape": [SCAN_STEPS, TRAIN_BATCH, 32, 32, 3]})
        ins.append({"name": "lr", "role": "lr", "shape": []})
    elif kind == "eval":
        ins.append({"name": "x", "role": "data_x", "shape": [EVAL_BATCH, 32, 32, 3]})
        ins.append({"name": "y", "role": "data_y", "shape": [EVAL_BATCH]})
    return ins


def _outputs(spec: InSpec, kind: str) -> list[str]:
    if kind == "train":
        return spec.trainable + ["loss", "correct"]
    if kind == "distill":
        return spec.trainable + ["loss"]
    return ["loss_sum", "correct"]


class Builder:
    def __init__(self, out_dir: str, verbose: bool = True):
        self.out_dir = out_dir
        self.verbose = verbose
        self.manifest: dict = {
            "version": 1,
            "kernel_backend": kconv.get_default_backend(),
            "train_batch": TRAIN_BATCH,
            "scan_steps": SCAN_STEPS,
            "eval_batch": EVAL_BATCH,
            "models": {},
        }

    def _lower(self, tag: str, name: str, fn, args, spec: InSpec, kind: str, extra: dict):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        rel = f"{tag}/{name}.hlo.txt"
        path = os.path.join(self.out_dir, rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(text)
        entry = {
            "path": rel,
            "kind": kind,
            "inputs": _input_entries(spec, kind),
            "outputs": _outputs(spec, kind),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            **extra,
        }
        self.manifest["models"][tag]["artifacts"][name] = entry
        if self.verbose:
            print(f"  {tag}/{name}: {len(text)/1e6:.2f} MB HLO in {time.time()-t0:.1f}s")

    def build_model(self, cfg: ModelCfg, profl: bool = True, depthfl: bool = True):
        """Lower every artifact for one model tag.

        Memory coefficients are computed twice: for the mini model actually
        executed (``mem``) and for its paper-width twin at width 64
        (``mem_paper``). The Rust memory substrate uses ``mem_paper`` so
        client participation reproduces the paper's 100-900 MB device
        dynamics while the compute stays laptop-scale (DESIGN.md
        §Substitutions).
        """
        mdl = build(cfg)
        paper = build(ModelCfg(cfg.family, 64, cfg.num_classes, width_ratio=cfg.width_ratio))
        tag = cfg.tag
        T = mdl.num_blocks
        full_spec = graphs.submodel_shapes(mdl, T)
        paper_full_spec = graphs.submodel_shapes(paper, T)

        # Union of every parameter the Rust store must hold for this tag.
        all_params: dict[str, list[int]] = {}

        def note(spec: InSpec):
            for n in spec.trainable + spec.frozen:
                all_params[n] = list(spec.shapes[n])

        block_names = []
        for t in range(1, T + 1):
            from . import ops as O

            names = list(O.param_shapes(mdl.blocks[t - 1], mdl.block_prefix(t)).keys())
            block_names.append(names)

        self.manifest["models"][tag] = {
            "family": cfg.family,
            "width": cfg.width,
            "num_classes": cfg.num_classes,
            "width_ratio": cfg.width_ratio,
            "image_size": cfg.image_size,
            "num_blocks": T,
            "block_param_counts": block_param_counts(mdl),
            "block_params": block_names,
            "artifacts": {},
            "mem": {
                "train_full": memory.train_full_mem(mdl).to_json(),
                "eval_full": memory.eval_mem(mdl, full_spec).to_json(),
                "output_layer": memory.output_layer_mem(mdl).to_json(),
            },
            "mem_paper": {
                "train_full": memory.train_full_mem(paper).to_json(),
                "eval_full": memory.eval_mem(paper, paper_full_spec).to_json(),
                "output_layer": memory.output_layer_mem(paper).to_json(),
            },
        }

        if profl:
            for t in range(1, T + 1):
                fn, spec = graphs.make_train_step(mdl, t)
                note(spec)
                self._lower(
                    tag, f"train_t{t}", fn, _train_example_args(spec), spec, "train",
                    {"step": t, "mem": memory.train_step_mem(mdl, t, spec).to_json(),
                     "mem_paper": memory.train_step_mem(paper, t).to_json()},
                )
                # Output-module-only variant (lowest-memory clients).
                fo, so = self._op_only(mdl, t, spec)
                self._lower(
                    tag, f"train_op_t{t}", fo, _train_example_args(so), so, "train",
                    {"step": t, "mem": memory.output_layer_mem(mdl).to_json(),
                     "mem_paper": memory.output_layer_mem(paper).to_json()},
                )
                fe, se = graphs.make_eval_sub(mdl, t)
                self._lower(
                    tag, f"eval_t{t}", fe, _eval_example_args(se), se, "eval",
                    {"step": t, "mem": memory.eval_mem(mdl, se).to_json(),
                     "mem_paper": memory.eval_mem(paper, graphs.submodel_shapes(paper, t)).to_json()},
                )
            for t in range(2, T + 1):
                fd, sd = graphs.make_distill_step(mdl, t)
                note(sd)
                _, psd = graphs.make_distill_step(paper, t)
                self._lower(
                    tag, f"distill_t{t}", fd, _distill_example_args(sd), sd, "distill",
                    {"step": t, "mem": memory.distill_mem(mdl, t, sd).to_json(),
                     "mem_paper": memory.distill_mem(paper, t, psd).to_json()},
                )

        # Full-model end-to-end (ExclusiveFL on r=1; HeteroFL/AllSmall on
        # their width-ratio variant tags).
        ff, sf = graphs.make_train_full(mdl)
        note(sf)
        self._lower(
            tag, "train_full", ff, _train_example_args(sf), sf, "train",
            {"mem": memory.train_full_mem(mdl).to_json(),
             "mem_paper": memory.train_full_mem(paper).to_json()},
        )
        if not profl:
            fe, se = graphs.make_eval_sub(mdl, T)
            self._lower(
                tag, f"eval_t{T}", fe, _eval_example_args(se), se, "eval",
                {"step": T, "mem": memory.eval_mem(mdl, se).to_json(),
                 "mem_paper": memory.eval_mem(paper, paper_full_spec).to_json()},
            )

        if depthfl:
            for d in range(1, T + 1):
                fd, sd = graphs.make_depthfl_train(mdl, d)
                note(sd)
                self._lower(
                    tag, f"depthfl_train_d{d}", fd, _train_example_args(sd), sd, "train",
                    {"depth": d, "mem": memory.depthfl_mem(mdl, d).to_json(),
                     "mem_paper": memory.depthfl_mem(paper, d).to_json()},
                )
            fe, se = graphs.make_depthfl_eval(mdl)
            self._lower(
                tag, "depthfl_eval", fe, _eval_example_args(se), se, "eval",
                {"mem": memory.eval_mem(mdl, se).to_json(),
                 "mem_paper": memory.eval_mem(paper, graphs.depthfl_shapes(paper, T)).to_json()},
            )

        self.manifest["models"][tag]["params"] = all_params

    @staticmethod
    def _op_only(mdl: ModelDef, t: int, spec: InSpec):
        """Variant of train_t{t} with only the output-module linear (or the
        head at t=T) trainable; everything else frozen."""
        op_names = [n for n in spec.trainable if n.startswith(("op/", "head/fc"))]
        so = InSpec(
            trainable=op_names,
            frozen=[n for n in spec.trainable if n not in op_names] + spec.frozen,
            shapes=spec.shapes,
        )
        fn, _ = graphs.make_train_step(mdl, t)
        # Re-wrap: the underlying graph is the same; we re-partition args.
        full = spec

        def fo(*args):
            nt, nf = len(so.trainable), len(so.frozen)
            by_name = dict(zip(so.trainable + so.frozen, args[: nt + nf]))
            xs, ys, lr = args[nt + nf :]
            inner_args = (
                [by_name[n] for n in full.trainable]
                + [by_name[n] for n in full.frozen]
                + [xs, ys, lr]
            )
            out = fn(*inner_args)
            new_by_name = dict(zip(full.trainable, out[: len(full.trainable)]))
            # Only the op params take their updated values.
            return tuple(new_by_name[n] for n in so.trainable) + out[-2:]

        return fo, so

    def write(self):
        path = os.path.join(self.out_dir, "manifest.json")
        os.makedirs(self.out_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        n_art = sum(len(m["artifacts"]) for m in self.manifest["models"].values())
        print(f"wrote {path}: {len(self.manifest['models'])} models, {n_art} artifacts")


def parse_model(s: str) -> ModelCfg:
    """'resnet18:8:10' -> ModelCfg(family, width, classes)."""
    fam, width, classes = s.split(":")
    return ModelCfg(fam, int(width), int(classes))


def perf_report(width: int = 8):
    """L1 perf accounting: VMEM footprint + MXU utilization of the GEMM
    schedule for every conv (DESIGN.md §Perf). `width` selects the model
    scale: 8 = the executed minis, 64 = the paper-width architecture the
    schedule is actually designed for (K/N reach the 128-wide MXU tiles)."""
    cfg = ModelCfg("resnet18", width, 10)
    mdl = build(cfg)
    print(f"Pallas GEMM tile 128x128x128: VMEM {vmem_bytes()/1024:.0f} KiB (budget ~16 MiB)")
    print(f"{'conv (block)':<28}{'M':>8}{'K':>7}{'N':>6}{'MXU util':>10}")
    from . import ops as O

    hwc = (32, 32, 3)
    for t, blk in enumerate(mdl.blocks, 1):
        for op in blk:
            convs = []
            if op.kind == "conv":
                convs = [(op.k, op.ci, op.co, op.stride)]
            elif op.kind == "basic":
                convs = [(op.k, op.ci, op.co, op.stride), (op.k, op.co, op.co, 1)]
            o = O.out_shape(op, hwc)
            for k, ci, co, s in convs:
                m = TRAIN_BATCH * o[0] * o[1]
                kk = k * k * ci
                print(
                    f"{'b'+str(t)+'/'+op.name:<28}{m:>8}{kk:>7}{co:>6}"
                    f"{mxu_utilization(m, co, kk):>10.2f}"
                )
            hwc = o


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--models", default=None, help="comma list fam:width:classes")
    ap.add_argument("--set", choices=["default", "full"], default="default")
    ap.add_argument("--kernels", choices=["native", "pallas"], default="native")
    ap.add_argument("--no-depthfl", action="store_true")
    ap.add_argument("--no-ratios", action="store_true")
    ap.add_argument("--report", action="store_true", help="print L1 perf accounting and exit")
    ap.add_argument("--report-width", type=int, default=8)
    args = ap.parse_args()

    if args.report:
        perf_report(args.report_width)
        return

    kconv.set_default_backend(args.kernels)
    specs = (
        [parse_model(s) for s in args.models.split(",")]
        if args.models
        else [parse_model(s) for s in (FULL_SET if args.set == "full" else DEFAULT_SET)]
    )

    b = Builder(os.path.abspath(args.out_dir))
    for cfg in specs:
        print(f"[{cfg.tag}]")
        b.build_model(cfg, profl=True, depthfl=not args.no_depthfl)
        if not args.no_ratios:
            for r in WIDTH_RATIOS:
                rcfg = ModelCfg(cfg.family, cfg.width, cfg.num_classes, width_ratio=r)
                print(f"[{rcfg.tag}]")
                b.build_model(rcfg, profl=False, depthfl=False)
    b.write()


if __name__ == "__main__":
    main()
