"""Model zoo: block-partitioned ResNet18/34 and VGG11/16_bn (L2).

Block partitioning follows the paper exactly (§4.1):

* ResNet18/34 — 4 blocks = the 4 residual stages; the stem conv travels
  with block 1 (this reproduces Table 5's per-block parameter ratios).
* VGG11_bn — 8 convs, maxpool after every 2; 2 blocks = convs 1-4 / 5-8.
* VGG16_bn — 13 convs, maxpool after every 4; 3 blocks = 4 / 4 / 5 convs.
* Heads are AdaptiveAvgPool((1,1)) + a single linear layer (paper §4.1).

``width`` is the base channel count (64 in the paper; the mini defaults
used by the benches keep the same topology at reduced width — ratios, not
absolute sizes, drive every paper claim we reproduce; see DESIGN.md).

Surrogates: each block t has a θ_{t,Conv} output-module component — a
single stride-matched conv+bn_relu mapping the block's input shape to its
output shape, preserving the block's "position" in the network (§3.2).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from . import ops as O

FAMILIES = ("resnet18", "resnet34", "vgg11", "vgg16")


@dataclass(frozen=True)
class ModelCfg:
    family: str
    width: int  # base channels (paper: 64)
    num_classes: int
    image_size: int = 32
    width_ratio: float = 1.0  # HeteroFL/AllSmall channel scaling

    @property
    def tag(self) -> str:
        r = f"_r{self.width_ratio:g}" if self.width_ratio != 1.0 else ""
        return f"{self.family}_w{self.width}_c{self.num_classes}{r}"


def _scale(c: int, ratio: float) -> int:
    """HeteroFL-style channel scaling: first ⌈ratio·C⌉ channels."""
    return max(1, math.ceil(c * ratio))


@dataclass
class ModelDef:
    """Blocks + head + surrogates, all as op-lists (see ops.py)."""

    cfg: ModelCfg
    blocks: list[list[O.Op]]
    head: list[O.Op]  # gap + dense(Ct -> classes)
    surrogates: list[list[O.Op] | None]  # per block; [0] unused (never distilled)

    @property
    def num_blocks(self) -> int:
        return len(self.blocks)

    def block_prefix(self, t: int) -> str:
        """Parameter prefix of block t (1-based, like the paper)."""
        return f"b{t}/"

    def block_in_hwc(self, t: int) -> tuple[int, int, int]:
        hwc = (self.cfg.image_size, self.cfg.image_size, 3)
        for i in range(t - 1):
            hwc = O.analyze_ops(self.blocks[i], hwc).out_hwc
        return hwc

    def block_out_hwc(self, t: int) -> tuple[int, int, int]:
        return O.analyze_ops(self.blocks[t - 1], self.block_in_hwc(t)).out_hwc


def build(cfg: ModelCfg) -> ModelDef:
    if cfg.family in ("resnet18", "resnet34"):
        return _build_resnet(cfg)
    if cfg.family in ("vgg11", "vgg16"):
        return _build_vgg(cfg)
    raise ValueError(f"unknown family {cfg.family}")


# ---------------------------------------------------------------------------
# ResNet
# ---------------------------------------------------------------------------

_RESNET_DEPTHS = {"resnet18": [2, 2, 2, 2], "resnet34": [3, 4, 6, 3]}


def _build_resnet(cfg: ModelCfg) -> ModelDef:
    depths = _RESNET_DEPTHS[cfg.family]
    w = cfg.width
    r = cfg.width_ratio
    widths = [_scale(w, r), _scale(2 * w, r), _scale(4 * w, r), _scale(8 * w, r)]

    blocks: list[list[O.Op]] = []
    # Block 1: stem + stage 1 (stride 1).
    b1: list[O.Op] = [
        O.conv_op("stem/conv", 3, widths[0], k=3, stride=1),
        O.bn_relu_op("stem/bn", widths[0]),
    ]
    ci = widths[0]
    for i in range(depths[0]):
        b1.append(O.basic_op(f"u{i}", ci, widths[0], stride=1))
        ci = widths[0]
    blocks.append(b1)
    # Blocks 2..4: stages 2..4, first unit stride 2.
    for s in range(1, 4):
        blk: list[O.Op] = []
        for i in range(depths[s]):
            stride = 2 if i == 0 else 1
            blk.append(O.basic_op(f"u{i}", ci, widths[s], stride=stride))
            ci = widths[s]
        blocks.append(blk)

    head = [O.gap_op(), O.dense_op("fc", widths[3], cfg.num_classes)]
    surrogates = _make_surrogates(cfg, blocks)
    return ModelDef(cfg, blocks, head, surrogates)


# ---------------------------------------------------------------------------
# VGG
# ---------------------------------------------------------------------------

_VGG_CHANNELS = {
    # paper-modified variants: VGG11 pools after every 2 convs,
    # VGG16 after every 4 (see §4.1).
    "vgg11": ([64, 128, 256, 256, 512, 512, 512, 512], 2, [4, 4]),
    "vgg16": ([64, 64, 128, 128, 256, 256, 256, 512, 512, 512, 512, 512, 512], 4, [4, 4, 5]),
}


def _build_vgg(cfg: ModelCfg) -> ModelDef:
    chans, pool_every, block_sizes = _VGG_CHANNELS[cfg.family]
    r = cfg.width_ratio
    base = cfg.width  # paper width 64; mini widths scale all channels by w/64
    chans = [_scale(c * base // 64 if base != 64 else c, r) for c in chans]

    convs: list[O.Op] = []
    ci = 3
    for i, co in enumerate(chans):
        convs.append(O.conv_op(f"conv{i}", ci, co, k=3, stride=1))
        convs.append(O.bn_relu_op(f"bn{i}", co))
        if (i + 1) % pool_every == 0:
            convs.append(O.maxpool_op())
        ci = co

    # Split the flat conv list into the paper's blocks by conv count.
    blocks: list[list[O.Op]] = []
    it = iter(convs)
    flat = list(convs)
    idx = 0
    for nconvs in block_sizes:
        blk: list[O.Op] = []
        seen = 0
        while idx < len(flat) and seen < nconvs:
            op = flat[idx]
            blk.append(op)
            if op.kind == "conv":
                seen += 1
            idx += 1
        # carry trailing bn/pool ops that belong to the last conv.
        while idx < len(flat) and flat[idx].kind in ("bn_relu", "maxpool"):
            blk.append(flat[idx])
            idx += 1
        blocks.append(blk)
    assert idx == len(flat), "vgg split lost ops"

    head = [O.gap_op(), O.dense_op("fc", chans[-1], cfg.num_classes)]
    surrogates = _make_surrogates(cfg, blocks)
    return ModelDef(cfg, blocks, head, surrogates)


# ---------------------------------------------------------------------------
# Surrogates (θ_Conv output-module components)
# ---------------------------------------------------------------------------


def _make_surrogates(cfg: ModelCfg, blocks: list[list[O.Op]]) -> list[list[O.Op] | None]:
    """One conv+bn_relu per block, stride = the block's total downsampling,
    channels = block in→out. Mimics the block's position (§3.2)."""
    surrogates: list[list[O.Op] | None] = [None]  # block 1 is never replaced
    hwc = (cfg.image_size, cfg.image_size, 3)
    for t, blk in enumerate(blocks, start=1):
        out = O.analyze_ops(blk, hwc).out_hwc
        if t >= 2:
            stride = hwc[0] // out[0] if out[0] else 1
            surrogates.append(
                [
                    O.conv_op("conv", hwc[2], out[2], k=3, stride=max(1, stride)),
                    O.bn_relu_op("bn", out[2]),
                ]
            )
        hwc = out
    return surrogates


# ---------------------------------------------------------------------------
# Whole-model parameter helpers
# ---------------------------------------------------------------------------


def model_param_shapes(mdl: ModelDef) -> dict[str, tuple[int, ...]]:
    """All block + head parameters (no surrogates), in block order."""
    shapes: dict[str, tuple[int, ...]] = {}
    for t, blk in enumerate(mdl.blocks, start=1):
        shapes.update(O.param_shapes(blk, mdl.block_prefix(t)))
    shapes.update(O.param_shapes(mdl.head, "head/"))
    return shapes


def block_param_counts(mdl: ModelDef) -> list[int]:
    """Per-block parameter totals (Table 5)."""
    import numpy as np

    counts = []
    for t, blk in enumerate(mdl.blocks, start=1):
        shapes = O.param_shapes(blk, mdl.block_prefix(t))
        counts.append(int(sum(np.prod(s) for s in shapes.values())))
    return counts
