"""Analytical training-memory model (the paper's "memory wall").

The peak training footprint of a sub-model is decomposed exactly the way
the paper reasons about it (§1, §4.5 / Fig 6):

  peak = P_all·4          (parameters, frozen + trainable)
       + P_tr·4           (gradients for the trainable part; plain SGD —
                           no optimizer state)
       + A_tr·4·batch     (activations retained for backward through the
                           trainable sub-graph — the dominant term for
                           early blocks, whose spatial dims are largest)
       + S_fr·4·batch     (streaming peak of the frozen forward prefix:
                           only in+out of one layer live at a time)

Freezing a block removes its A term entirely and leaves only the S term —
that is the mechanism by which ProFL "breaks the memory wall".

These coefficients are computed from the op-list IR (ops.analyze_ops) and
exported per-artifact into the manifest; the Rust `memory` module applies
them (with batch size + contention jitter) to decide client participation.
Fig 6 is regenerated from exactly these numbers.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass

from . import ops as O
from .graphs import InSpec, depthfl_shapes, submodel_shapes
from .models import ModelDef

BYTES = 4  # f32


@dataclass
class MemCoeffs:
    """Manifest entry: bytes = fixed_bytes + per_sample_bytes * batch."""

    fixed_bytes: int
    per_sample_bytes: int
    params_total: int
    params_trainable: int

    def bytes_at(self, batch: int) -> int:
        return self.fixed_bytes + self.per_sample_bytes * batch

    def to_json(self) -> dict:
        return asdict(self)


def _count(shapes: dict[str, tuple[int, ...]], names: list[str]) -> int:
    total = 0
    for n in names:
        c = 1
        for d in shapes[n]:
            c *= d
        total += c
    return total


def _trainable_act_per_sample(mdl: ModelDef, t: int) -> int:
    """Retained-for-backward activations of the step-t trainable sub-graph:
    block t + surrogate tail + head/op linear."""
    T = mdl.num_blocks
    in_hwc = mdl.block_in_hwc(t)
    acts = O.analyze_ops(mdl.blocks[t - 1], in_hwc).stored_act_per_sample
    hwc = mdl.block_out_hwc(t)
    if t == T:
        acts += O.analyze_ops(mdl.head, hwc).stored_act_per_sample
    else:
        for u in range(t + 1, T + 1):
            st = O.analyze_ops(mdl.surrogates[u - 1], hwc)
            acts += st.stored_act_per_sample
            hwc = st.out_hwc
        acts += hwc[2] + mdl.cfg.num_classes  # gap + op/fc
    return acts


def _frozen_stream_per_sample(mdl: ModelDef, t: int) -> int:
    """Peak live set while streaming the frozen prefix (blocks 1..t-1)."""
    peak = mdl.cfg.image_size * mdl.cfg.image_size * 3  # the input batch
    hwc = (mdl.cfg.image_size, mdl.cfg.image_size, 3)
    for u in range(1, t):
        st = O.analyze_ops(mdl.blocks[u - 1], hwc)
        peak = max(peak, st.peak_stream_per_sample)
        hwc = st.out_hwc
    return peak


def train_step_mem(mdl: ModelDef, t: int, spec: InSpec | None = None) -> MemCoeffs:
    """Memory model for the step-t sub-model train step (grow/shrink)."""
    spec = spec or submodel_shapes(mdl, t)
    p_all = _count(spec.shapes, spec.trainable + spec.frozen)
    p_tr = _count(spec.shapes, spec.trainable)
    acts = _trainable_act_per_sample(mdl, t)
    stream = _frozen_stream_per_sample(mdl, t)
    return MemCoeffs(
        fixed_bytes=(p_all + p_tr) * BYTES,
        per_sample_bytes=(acts + stream) * BYTES,
        params_total=p_all,
        params_trainable=p_tr,
    )


def train_full_mem(mdl: ModelDef) -> MemCoeffs:
    """Full end-to-end training: every block's activations are retained."""
    T = mdl.num_blocks
    spec = submodel_shapes(mdl, T)
    p_all = _count(spec.shapes, spec.trainable + spec.frozen)
    acts = 0
    hwc = (mdl.cfg.image_size, mdl.cfg.image_size, 3)
    acts += hwc[0] * hwc[1] * hwc[2]  # input batch
    for u in range(1, T + 1):
        st = O.analyze_ops(mdl.blocks[u - 1], hwc)
        acts += st.stored_act_per_sample
        hwc = st.out_hwc
    acts += O.analyze_ops(mdl.head, hwc).stored_act_per_sample
    return MemCoeffs(
        fixed_bytes=2 * p_all * BYTES,
        per_sample_bytes=acts * BYTES,
        params_total=p_all,
        params_trainable=p_all,
    )


def distill_mem(mdl: ModelDef, t: int, spec: InSpec) -> MemCoeffs:
    """Distilling block t into its surrogate: frozen forward through
    blocks 1..t (streaming) + backward through the single surrogate conv."""
    p_all = _count(spec.shapes, spec.trainable + spec.frozen)
    p_tr = _count(spec.shapes, spec.trainable)
    in_hwc = mdl.block_in_hwc(t)
    st = O.analyze_ops(mdl.surrogates[t - 1], in_hwc)
    acts = st.stored_act_per_sample + st.peak_stream_per_sample
    stream = _frozen_stream_per_sample(mdl, t + 1)
    return MemCoeffs(
        fixed_bytes=(p_all + p_tr) * BYTES,
        per_sample_bytes=(acts + stream) * BYTES,
        params_total=p_all,
        params_trainable=p_tr,
    )


def depthfl_mem(mdl: ModelDef, d: int) -> MemCoeffs:
    """DepthFL depth-d local model: blocks 1..d all trainable (activations
    retained everywhere — DepthFL does not freeze, which is why its
    first-block memory demand excludes low-memory clients; §4.2)."""
    spec = depthfl_shapes(mdl, d)
    p_all = _count(spec.shapes, spec.trainable)
    acts = mdl.cfg.image_size * mdl.cfg.image_size * 3
    hwc = (mdl.cfg.image_size, mdl.cfg.image_size, 3)
    for u in range(1, d + 1):
        st = O.analyze_ops(mdl.blocks[u - 1], hwc)
        acts += st.stored_act_per_sample
        hwc = st.out_hwc
        acts += hwc[2] + mdl.cfg.num_classes  # per-block classifier
    return MemCoeffs(
        fixed_bytes=2 * p_all * BYTES,
        per_sample_bytes=acts * BYTES,
        params_total=p_all,
        params_trainable=p_all,
    )


def eval_mem(mdl: ModelDef, spec: InSpec) -> MemCoeffs:
    """Inference: params + streaming peak (no retained activations)."""
    p_all = _count(spec.shapes, spec.trainable + spec.frozen)
    T = mdl.num_blocks
    return MemCoeffs(
        fixed_bytes=p_all * BYTES,
        per_sample_bytes=_frozen_stream_per_sample(mdl, T + 1) * BYTES,
        params_total=p_all,
        params_trainable=0,
    )


def output_layer_mem(mdl: ModelDef) -> MemCoeffs:
    """§4.1 fallback: clients too small for any block train only the output
    layer (frozen streaming forward + linear-layer backward)."""
    T = mdl.num_blocks
    c_last = mdl.block_out_hwc(T)[2]
    p_head = c_last * mdl.cfg.num_classes + mdl.cfg.num_classes
    spec = submodel_shapes(mdl, T)
    p_all = _count(spec.shapes, spec.trainable + spec.frozen)
    stream = _frozen_stream_per_sample(mdl, T + 1)
    return MemCoeffs(
        fixed_bytes=(p_all + p_head) * BYTES,
        per_sample_bytes=(stream + c_last + mdl.cfg.num_classes) * BYTES,
        params_total=p_all,
        params_trainable=p_head,
    )
