"""Op-list model IR — the single source of truth for L2 model structure.

Every model family (ResNet18/34, VGG11/16_bn) is described as a list of
*blocks* (the paper's θ_1..θ_T), each a flat list of ``Op`` records, plus a
head (global-avg-pool + linear) and one *surrogate* op per block (the
``θ_{t,Conv}`` output-module component of §3.2).

From this one IR we derive, with no duplicated shape logic:

* parameter initialization (``init_ops``)
* the forward pass (``forward_ops`` — dispatches L1 kernels)
* static activation shapes (``out_shape`` — feeds the memory model)
* per-block parameter inventories (the artifact manifest, Table 5)

Normalization note: we use *static* batch-norm (batch statistics in both
train and eval, no running stats), the standard choice for FL
reproductions (HeteroFL does the same): aggregated running stats are
ill-defined across Non-IID clients and would add mutable state to every
artifact signature. BN scale/shift remain learnable parameters.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import conv as kconv
from .kernels import fused, ref

EPS = 1e-5


@dataclass(frozen=True)
class Op:
    """One layer. ``kind`` ∈ conv | bn_relu | bn | relu | maxpool | gap |
    dense | basic (ResNet basic block, composite)."""

    kind: str
    name: str = ""
    k: int = 3
    stride: int = 1
    ci: int = 0
    co: int = 0
    downsample: bool = False  # basic: 1x1 conv on the skip path


def conv_op(name: str, ci: int, co: int, k: int = 3, stride: int = 1) -> Op:
    return Op("conv", name, k=k, stride=stride, ci=ci, co=co)


def bn_relu_op(name: str, c: int) -> Op:
    return Op("bn_relu", name, ci=c, co=c)


def basic_op(name: str, ci: int, co: int, stride: int = 1) -> Op:
    return Op("basic", name, ci=ci, co=co, stride=stride, downsample=(stride != 1 or ci != co))


def maxpool_op() -> Op:
    return Op("maxpool")


def gap_op() -> Op:
    return Op("gap")


def dense_op(name: str, ci: int, co: int) -> Op:
    return Op("dense", name, ci=ci, co=co)


# ---------------------------------------------------------------------------
# Parameter specs / init
# ---------------------------------------------------------------------------


def param_shapes(ops: list[Op], prefix: str = "") -> dict[str, tuple[int, ...]]:
    """Name → shape for every parameter an op-list owns, in layer order."""
    shapes: dict[str, tuple[int, ...]] = {}
    for op in ops:
        p = f"{prefix}{op.name}"
        if op.kind == "conv":
            shapes[f"{p}/w"] = (op.k, op.k, op.ci, op.co)
        elif op.kind in ("bn_relu", "bn"):
            shapes[f"{p}/scale"] = (op.ci,)
            shapes[f"{p}/shift"] = (op.ci,)
        elif op.kind == "dense":
            shapes[f"{p}/w"] = (op.ci, op.co)
            shapes[f"{p}/b"] = (op.co,)
        elif op.kind == "basic":
            shapes[f"{p}/conv1/w"] = (op.k, op.k, op.ci, op.co)
            shapes[f"{p}/bn1/scale"] = (op.co,)
            shapes[f"{p}/bn1/shift"] = (op.co,)
            shapes[f"{p}/conv2/w"] = (op.k, op.k, op.co, op.co)
            shapes[f"{p}/bn2/scale"] = (op.co,)
            shapes[f"{p}/bn2/shift"] = (op.co,)
            if op.downsample:
                shapes[f"{p}/ds/w"] = (1, 1, op.ci, op.co)
                shapes[f"{p}/dsbn/scale"] = (op.co,)
                shapes[f"{p}/dsbn/shift"] = (op.co,)
    return shapes


def init_ops(key: jax.Array, ops: list[Op], prefix: str = "") -> dict[str, jax.Array]:
    """He-init convs/dense, unit/zero BN — matches torchvision defaults."""
    params: dict[str, jax.Array] = {}
    for name, shape in param_shapes(ops, prefix).items():
        key, sub = jax.random.split(key)
        if name.endswith("/w") and len(shape) == 4:
            fan_in = shape[0] * shape[1] * shape[2]
            params[name] = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)
        elif name.endswith("/w"):
            fan_in = shape[0]
            params[name] = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)
        elif name.endswith("/scale"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:  # shift / bias
            params[name] = jnp.zeros(shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _batch_norm(x: jax.Array) -> jax.Array:
    """Normalize over (N, H, W) with batch statistics (static BN)."""
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axis=axes, keepdims=True)
    var = jnp.var(x, axis=axes, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + EPS)


def _bn_relu(params, p: str, x: jax.Array) -> jax.Array:
    xn = _batch_norm(x)
    if kconv.get_default_backend() == "pallas":
        return fused.scale_shift_relu_grad(xn, params[f"{p}/scale"], params[f"{p}/shift"])
    return ref.scale_shift_relu_ref(xn, params[f"{p}/scale"], params[f"{p}/shift"])


def _bn(params, p: str, x: jax.Array) -> jax.Array:
    return _batch_norm(x) * params[f"{p}/scale"] + params[f"{p}/shift"]


def _add_relu(x: jax.Array, skip: jax.Array) -> jax.Array:
    if kconv.get_default_backend() == "pallas":
        return fused.residual_add_relu_grad(x, skip)
    return ref.residual_add_relu_ref(x, skip)


def forward_ops(
    params: dict[str, jax.Array], ops: list[Op], x: jax.Array, prefix: str = ""
) -> jax.Array:
    """Interpret an op-list. x is NHWC (or (N, C) once past a ``gap``)."""
    for op in ops:
        p = f"{prefix}{op.name}"
        if op.kind == "conv":
            x = kconv.conv2d(x, params[f"{p}/w"], stride=op.stride)
        elif op.kind == "bn_relu":
            x = _bn_relu(params, p, x)
        elif op.kind == "bn":
            x = _bn(params, p, x)
        elif op.kind == "relu":
            x = jax.nn.relu(x)
        elif op.kind == "maxpool":
            x = ref.max_pool_2x2_ref(x)
        elif op.kind == "gap":
            x = ref.global_avg_pool_ref(x)
        elif op.kind == "dense":
            x = x @ params[f"{p}/w"] + params[f"{p}/b"]
        elif op.kind == "basic":
            h = kconv.conv2d(x, params[f"{p}/conv1/w"], stride=op.stride)
            h = _bn_relu(params, f"{p}/bn1", h)
            h = kconv.conv2d(h, params[f"{p}/conv2/w"], stride=1)
            h = _bn(params, f"{p}/bn2", h)
            if op.downsample:
                skip = kconv.conv2d(x, params[f"{p}/ds/w"], stride=op.stride)
                skip = _bn(params, f"{p}/dsbn", skip)
            else:
                skip = x
            x = _add_relu(h, skip)
        else:  # pragma: no cover - construction bug
            raise ValueError(f"unknown op kind {op.kind}")
    return x


# ---------------------------------------------------------------------------
# Static shape / memory accounting
# ---------------------------------------------------------------------------


def out_shape(op: Op, hwc: tuple[int, int, int]) -> tuple[int, int, int]:
    """Output (H, W, C) of one op given its input (H, W, C). (N,C) tensors
    are modelled as (1, 1, C)."""
    h, w, c = hwc
    if op.kind == "conv":
        s = op.stride
        return (-(-h // s), -(-w // s), op.co)
    if op.kind in ("bn_relu", "bn", "relu"):
        return (h, w, c)
    if op.kind == "maxpool":
        return (h // 2, w // 2, c)
    if op.kind == "gap":
        return (1, 1, c)
    if op.kind == "dense":
        return (1, 1, op.co)
    if op.kind == "basic":
        s = op.stride
        return (-(-h // s), -(-w // s), op.co)
    raise ValueError(op.kind)


def stored_activations(op: Op, in_hwc: tuple[int, int, int]) -> int:
    """Per-sample element count of intermediates that must be *retained for
    backward* through this op (the paper's memory-wall term).

    Rough but layer-faithful: each conv/bn/relu keeps its output; a basic
    block keeps conv1/bn1/conv2/skip/out. The frozen prefix keeps nothing
    (forward-only, buffers freed as consumed) — that asymmetry is exactly
    what ProFL exploits.
    """
    h, w, c = in_hwc
    oh, ow, oc = out_shape(op, in_hwc)
    if op.kind == "conv":
        return oh * ow * oc
    if op.kind in ("bn_relu", "bn", "relu"):
        return oh * ow * oc
    if op.kind == "maxpool":
        return oh * ow * oc
    if op.kind == "gap":
        return oc
    if op.kind == "dense":
        return op.co
    if op.kind == "basic":
        mid = oh * ow * oc
        skip = mid if op.downsample else 0
        return 4 * mid + skip  # conv1, bn1-relu, conv2-bn2, out (+ ds skip)
    raise ValueError(op.kind)


@dataclass
class OpListStats:
    """Aggregate accounting for an op-list at a given input shape."""

    params: int = 0
    stored_act_per_sample: int = 0  # elements kept for backward
    peak_stream_per_sample: int = 0  # max in+out live set (forward-only)
    flops_per_sample: int = 0
    out_hwc: tuple[int, int, int] = field(default=(0, 0, 0))


def analyze_ops(ops: list[Op], in_hwc: tuple[int, int, int]) -> OpListStats:
    st = OpListStats(out_hwc=in_hwc)
    hwc = in_hwc
    for op in ops:
        o = out_shape(op, hwc)
        st.params += sum(
            int(jnp.prod(jnp.array(s))) for s in param_shapes([op]).values()
        )
        st.stored_act_per_sample += stored_activations(op, hwc)
        live = hwc[0] * hwc[1] * hwc[2] + o[0] * o[1] * o[2]
        st.peak_stream_per_sample = max(st.peak_stream_per_sample, live)
        # MACs: convs + dense dominate.
        if op.kind == "conv":
            st.flops_per_sample += 2 * o[0] * o[1] * op.co * op.k * op.k * op.ci
        elif op.kind == "dense":
            st.flops_per_sample += 2 * op.ci * op.co
        elif op.kind == "basic":
            st.flops_per_sample += 2 * o[0] * o[1] * op.co * op.k * op.k * op.ci
            st.flops_per_sample += 2 * o[0] * o[1] * op.co * op.k * op.k * op.co
            if op.downsample:
                st.flops_per_sample += 2 * o[0] * o[1] * op.co * op.ci
        hwc = o
    st.out_hwc = hwc
    return st
