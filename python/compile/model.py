"""Compatibility shim: the L2 model lives in `models` (zoo) + `graphs`
(step-wise training graphs). This module re-exports the public surface
under the layout name `compile.model`."""
from .graphs import (  # noqa: F401
    make_depthfl_eval,
    make_depthfl_train,
    make_distill_step,
    make_eval_sub,
    make_train_full,
    make_train_step,
    submodel_shapes,
)
from .models import ModelCfg, ModelDef, build  # noqa: F401
