"""Convolution front-end with selectable backend (L1 dispatch).

``conv2d(x, w, stride, backend=...)``:

* ``"native"`` — ``lax.conv_general_dilated`` (XLA's fused conv). Default
  for the table-scale benches: on the CPU PJRT backend it is orders of
  magnitude faster than interpret-mode Pallas, and pytest pins the two
  backends to identical numerics, so the FL results are backend-invariant.
* ``"pallas"`` — im2col + the tiled Pallas GEMM (`kernels.matmul`), the
  TPU-shaped decomposition of the paper's conv hot-spot. Used by the
  kernel-variant artifacts and the quickstart e2e path.

The backend is threaded through the model as a module-level default so the
whole network lowers with one choice (set by ``aot.py --kernels``).
"""

from __future__ import annotations

import jax

from . import ref
from .matmul import matmul_grad

# Mutated only by aot.py / tests before tracing; never at runtime (the HLO
# is lowered once with whichever backend is active).
_DEFAULT_BACKEND = "native"


def set_default_backend(backend: str) -> None:
    """Select the conv backend used by subsequent model tracing."""
    global _DEFAULT_BACKEND
    assert backend in ("native", "pallas"), backend
    _DEFAULT_BACKEND = backend


def get_default_backend() -> str:
    return _DEFAULT_BACKEND


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    backend: str | None = None,
) -> jax.Array:
    """SAME-padded NHWC x HWIO conv through the selected backend."""
    backend = backend or _DEFAULT_BACKEND
    if backend == "native":
        return ref.conv2d_ref(x, w, stride=stride, padding="SAME")
    return conv2d_pallas(x, w, stride=stride)


def conv2d_pallas(x: jax.Array, w: jax.Array, *, stride: int = 1) -> jax.Array:
    """im2col + tiled Pallas GEMM. Numerically pinned to ``conv2d_ref``.

    GEMM dims: M = N*OH*OW (output pixels), K = KH*KW*Cin, N = Cout.
    For the mini models M dominates (batch 32 @ 32x32 -> M = 32768), which
    is exactly the axis the 128-row MXU tile wants to stream over.
    """
    n, h, w_, _ = x.shape
    kh, kw, _, co = w.shape
    oh = -(-h // stride)
    ow = -(-w_ // stride)
    patches = ref.im2col_patches(x, kh, kw, stride)  # (N*OH*OW, KH*KW*C)
    out = matmul_grad(patches, w.reshape(-1, co))
    return out.reshape(n, oh, ow, co)
