"""L1 — Pallas kernels for the ProFL compute hot-spots.

Modules:
  matmul — tiled GEMM (the im2col conv core), MXU/VMEM-shaped BlockSpec.
  fused  — BN-apply+ReLU and residual+ReLU epilogues.
  conv   — conv2d front-end dispatching native (XLA) vs pallas backends.
  ref    — pure-jnp oracles; the single source of truth for numerics.
"""
from . import conv, fused, matmul, ref  # noqa: F401
