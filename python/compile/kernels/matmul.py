"""Tiled Pallas GEMM — the L1 compute hot-spot.

ProFL's per-round compute is dominated by the convolutions of the block
being trained. On TPU the right decomposition is im2col + GEMM on the MXU
(not the CUDA threadblock/shared-memory scheme of GPU conv papers): the
systolic array wants dense (bm, bk) x (bk, bn) tiles streamed through VMEM.

BlockSpec schedule
------------------
grid = (M/bm, N/bn, K/bk), with K innermost so each (i, j) output tile stays
resident in VMEM while partial products accumulate over k — one HBM write
per output tile. Default tiles are 128x128x128: 3 * 128*128 * 4B = 192 KiB
of VMEM (f32), far under the ~16 MiB budget, and M/N/K multiples of 128 map
1:1 onto the 128x128 MXU. Inputs with ragged edges are zero-padded up front
and the result is cropped (padding waste is reported by ``aot.py --report``).

On this testbed the kernel runs under ``interpret=True`` (the CPU PJRT
client cannot execute Mosaic custom-calls), which lowers the same schedule
to plain HLO — numerics are identical to a real-TPU build, wall-clock is
not. Structure (tiling/fusion/traffic) is what we optimize here; see
DESIGN.md §Perf for the VMEM/MXU accounting.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    """One (bm, bn) output tile; grid axis 2 walks the K dimension.

    acc_ref is a VMEM scratch accumulator in f32; the output tile is only
    written on the last K step, so the kernel performs exactly one HBM
    store per output element.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    rem = x.shape[axis] % m
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - rem)
    return jnp.pad(x, pad)


@jax.custom_vjp
def matmul_grad(a: jax.Array, b: jax.Array) -> jax.Array:
    """Differentiable wrapper: both the forward GEMM and the two backward
    GEMMs (dA = g @ Bᵀ, dB = Aᵀ @ g) run through the Pallas kernel, so the
    training hot path stays on the MXU schedule in both directions."""
    return matmul(a, b)


def _matmul_fwd(a, b):
    return matmul(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    return matmul(g, b.T), matmul(a.T, g)


matmul_grad.defvjp(_matmul_fwd, _matmul_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
) -> jax.Array:
    """``a @ b`` via the tiled Pallas kernel. a: (M, K), b: (K, N).

    Ragged shapes are zero-padded to tile multiples and cropped after;
    accumulation is always f32 (matches ``ref.matmul_ref``).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    # Shrink tiles for small problems so the grid is never empty and we do
    # not inflate tiny GEMMs to 128^2 (keeps interpret-mode tests fast).
    bm = min(bm, max(8, 1 << (m - 1).bit_length()))
    bn = min(bn, max(8, 1 << (n - 1).bit_length()))
    bk = min(bk, max(8, 1 << (k - 1).bit_length()))
    ap = _pad_to(_pad_to(a, bm, 0), bk, 1)
    bp = _pad_to(_pad_to(b, bk, 0), bn, 1)
    mp, kp = ap.shape
    _, np_ = bp.shape
    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


def vmem_bytes(bm: int = 128, bn: int = 128, bk: int = 128, itemsize: int = 4) -> int:
    """VMEM footprint of one grid step: A-tile + B-tile + accumulator.

    Used by ``aot.py --report`` and DESIGN.md §Perf to check the schedule
    against the ~16 MiB/core VMEM budget.
    """
    return (bm * bk + bk * bn) * itemsize + bm * bn * 4


def mxu_utilization(m: int, n: int, k: int, bm: int = 128, bn: int = 128, bk: int = 128) -> float:
    """Fraction of MXU-issued MACs that are useful (non-padding).

    The kernel pads each dim up to its tile multiple; utilization is
    useful_macs / issued_macs. 1.0 when m, n, k are tile multiples.
    """
    ceil = lambda x, t: -(-x // t) * t
    useful = m * n * k
    issued = ceil(m, bm) * ceil(n, bn) * ceil(k, bk)
    return useful / issued
