"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an entry here with identical
semantics, written only with `jax.numpy` / `jax.lax` primitives. The pytest
suite asserts `assert_allclose(kernel(...), ref(...))` over a hypothesis
sweep of shapes and dtypes; these functions are the single source of truth
for kernel numerics.

They are also used directly by the L2 model when the aot pipeline is run
with ``--kernels native`` (the default for the large table benches, where
XLA's fused convolutions are much faster on the CPU PJRT backend than
interpret-mode Pallas). ``--kernels pallas`` swaps in the real kernels; the
lowered HLO is numerically pinned against this module by
``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain GEMM: ``a @ b`` with f32 accumulation.

    a: (M, K), b: (K, N) -> (M, N). Mirrors the Pallas kernel's behaviour of
    accumulating in float32 regardless of input dtype.
    """
    return jnp.matmul(
        a.astype(jnp.float32), b.astype(jnp.float32)
    ).astype(a.dtype)


def scale_shift_relu_ref(
    x: jax.Array, scale: jax.Array, shift: jax.Array
) -> jax.Array:
    """Fused BN-apply epilogue: ``relu(x * scale + shift)``.

    x: (..., C); scale/shift: (C,) broadcast over leading dims. This is the
    inference-form batch-norm (statistics already folded into scale/shift)
    followed by ReLU — the epilogue the Pallas kernel fuses so the
    activation tensor makes a single HBM round trip.
    """
    return jax.nn.relu(x * scale + shift)


def residual_add_relu_ref(x: jax.Array, skip: jax.Array) -> jax.Array:
    """Fused residual join: ``relu(x + skip)`` (ResNet basic-block tail)."""
    return jax.nn.relu(x + skip)


def conv2d_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: str | int = "SAME",
) -> jax.Array:
    """NHWC x HWIO convolution via ``lax.conv_general_dilated``.

    This is both the oracle for the im2col+GEMM Pallas path and the
    production conv used by the ``native`` kernel backend.
    """
    if isinstance(padding, int):
        pad = [(padding, padding), (padding, padding)]
    else:
        pad = padding
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def im2col_patches(x: jax.Array, kh: int, kw: int, stride: int) -> jax.Array:
    """Extract SAME-padded conv patches: (N,H,W,C) -> (N*OH*OW, KH*KW*C).

    The GEMM view of convolution: ``patches @ w.reshape(KH*KW*C, O)`` equals
    ``conv2d_ref(x, w, stride=stride, padding="SAME")`` (see tests). Used by
    the Pallas conv path so the only hot compute is the tiled matmul kernel.
    """
    n, h, w_, c = x.shape
    oh = -(-h // stride)
    ow = -(-w_ // stride)
    # SAME padding amounts (TF convention).
    pad_h = max((oh - 1) * stride + kh - h, 0)
    pad_w = max((ow - 1) * stride + kw - w_, 0)
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (pad_h // 2, pad_h - pad_h // 2),
            (pad_w // 2, pad_w - pad_w // 2),
            (0, 0),
        ),
    )
    patches = jax.lax.conv_general_dilated_patches(
        xp,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # conv_general_dilated_patches returns channels ordered as (C, KH, KW)
    # on the last axis; reorder to (KH, KW, C) to match w.reshape(-1, O).
    patches = patches.reshape(n, oh, ow, c, kh, kw)
    patches = patches.transpose(0, 1, 2, 4, 5, 3)
    return patches.reshape(n * oh * ow, kh * kw * c)


def global_avg_pool_ref(x: jax.Array) -> jax.Array:
    """AdaptiveAvgPool2d((1,1)) over NHWC -> (N, C)."""
    return jnp.mean(x, axis=(1, 2))


def max_pool_2x2_ref(x: jax.Array) -> jax.Array:
    """2x2/stride-2 max pool over NHWC (VGG downsampling)."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )
