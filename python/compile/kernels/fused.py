"""Fused element-wise Pallas kernels (BN-apply + ReLU, residual join).

On GPU the paper's models interleave conv → BN → ReLU, each a separate
global-memory round trip. The TPU re-think keeps the conv output tile in
VMEM and applies the normalize/activate epilogue before it is written back:
one HBM store instead of three loads + three stores. We express that as a
standalone row-tiled kernel here (composable with any producer) and fuse it
after the im2col GEMM in ``conv.py``.

Both kernels are 1-D row-tiled over a (R, C) view of the activation tensor:
grid = (R / br,), block = (br, C). C (the channel dim) is the minor axis so
the per-channel scale/shift vectors broadcast along lanes — the layout the
VPU wants. Run under ``interpret=True`` on this CPU testbed (see matmul.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scale_shift_relu_kernel(x_ref, scale_ref, shift_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...] * scale_ref[...] + shift_ref[...], 0.0)


def _residual_add_relu_kernel(x_ref, s_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...] + s_ref[...], 0.0)


@jax.custom_vjp
def scale_shift_relu_grad(x, scale, shift):
    """Differentiable fused BN-apply+ReLU (backward in plain jnp — the
    backward is bandwidth-bound elementwise work XLA fuses fine)."""
    return scale_shift_relu(x, scale, shift)


def _ssr_fwd(x, scale, shift):
    y = scale_shift_relu(x, scale, shift)
    return y, (x, scale, y)


def _ssr_bwd(res, g):
    x, scale, y = res
    m = (y > 0).astype(g.dtype) * g
    axes = tuple(range(x.ndim - 1))
    return m * scale, jnp.sum(m * x, axis=axes), jnp.sum(m, axis=axes)


scale_shift_relu_grad.defvjp(_ssr_fwd, _ssr_bwd)


@jax.custom_vjp
def residual_add_relu_grad(x, skip):
    """Differentiable fused residual join."""
    return residual_add_relu(x, skip)


def _rar_fwd(x, skip):
    y = residual_add_relu(x, skip)
    return y, (y,)


def _rar_bwd(res, g):
    (y,) = res
    m = (y > 0).astype(g.dtype) * g
    return m, m


residual_add_relu_grad.defvjp(_rar_fwd, _rar_bwd)


def _row_grid(r: int, br: int) -> tuple[int, int]:
    """Clamp the row tile to the problem and return (tile, steps)."""
    br = min(br, max(8, 1 << (r - 1).bit_length()))
    steps = -(-r // br)
    return br, steps


@functools.partial(jax.jit, static_argnames=("br",))
def scale_shift_relu(
    x: jax.Array, scale: jax.Array, shift: jax.Array, *, br: int = 256
) -> jax.Array:
    """``relu(x * scale + shift)`` with (C,) scale/shift over (..., C) x.

    Matches ``ref.scale_shift_relu_ref``. The leading dims are flattened to
    rows; rows are tiled so each grid step touches br*C elements in VMEM.
    """
    orig_shape = x.shape
    c = x.shape[-1]
    xr = x.reshape(-1, c)
    r = xr.shape[0]
    br, steps = _row_grid(r, br)
    pad = steps * br - r
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _scale_shift_relu_kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            # scale/shift are tiny; replicate the whole vector to every step.
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=True,
    )(xr, scale.astype(x.dtype), shift.astype(x.dtype))
    if pad:
        out = out[:r]
    return out.reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("br",))
def residual_add_relu(x: jax.Array, skip: jax.Array, *, br: int = 256) -> jax.Array:
    """``relu(x + skip)`` — the ResNet basic-block tail, fused in VMEM."""
    assert x.shape == skip.shape, (x.shape, skip.shape)
    orig_shape = x.shape
    c = x.shape[-1]
    xr = x.reshape(-1, c)
    sr = skip.reshape(-1, c)
    r = xr.shape[0]
    br, steps = _row_grid(r, br)
    pad = steps * br - r
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
        sr = jnp.pad(sr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _residual_add_relu_kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=True,
    )(xr, sr)
    if pad:
        out = out[:r]
    return out.reshape(orig_shape)
