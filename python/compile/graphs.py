"""Step-wise training/eval/distillation graphs (L2 → HLO artifacts).

Every function here returns ``(fn, in_spec)`` where ``fn`` is the jax
function ``aot.py`` lowers and ``in_spec`` names the ordered parameter
lists so the Rust runtime can marshal flat f32 buffers positionally:

  train:   (trainable…, frozen…, xs, ys, lr) -> (new_trainable…, loss, correct)
  distill: (trainable…, frozen…, xs, lr)     -> (new_trainable…, loss)
  eval:    (params…, x, y)                   -> (loss_sum, correct)

``xs``/``ys`` are *stacked* local batches ``(S, B, …)`` consumed by a
``lax.scan`` of S plain-SGD steps — one executable call per local epoch
chunk, which keeps the Rust↔PJRT crossing off the per-batch path (see
DESIGN.md §Perf).

Sub-model composition (paper §3.1/3.2): the step-t sub-model is
``[θ*_{1,F}, …, θ*_{t-1,F}, θ_t, θ_op]`` with
``θ_op = [θ_{t+1,Conv}, …, θ_{T,Conv}, θ_L]`` — frozen prefix, trainable
block, surrogate tail + linear. The same graph serves both progressive
model *shrinking* and *growing*; the two stages differ only in which
parameter values Rust feeds (random-init vs trained prefix) and in the
step order (T→2 vs 1→T).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from . import ops as O
from .models import ModelDef


@dataclass
class InSpec:
    """Ordered parameter-name lists for an artifact (goes in the manifest)."""

    trainable: list[str] = field(default_factory=list)
    frozen: list[str] = field(default_factory=list)
    # name -> shape for everything above
    shapes: dict[str, tuple[int, ...]] = field(default_factory=dict)


def _ordered(shapes: dict[str, tuple[int, ...]]) -> list[str]:
    """Deterministic parameter order: insertion order of the op-lists
    (layer order), which both sides reproduce from the manifest."""
    return list(shapes.keys())


# ---------------------------------------------------------------------------
# Sub-model forward pieces
# ---------------------------------------------------------------------------


def _forward_blocks(mdl: ModelDef, params, x, upto: int):
    """Blocks 1..upto (inclusive)."""
    for t in range(1, upto + 1):
        x = O.forward_ops(params, mdl.blocks[t - 1], x, mdl.block_prefix(t))
    return x


def _forward_output_module(mdl: ModelDef, params, x, t: int):
    """Surrogates t+1..T, then gap + the module's own linear ``op/fc``;
    at t == T this is the model head itself."""
    T = mdl.num_blocks
    if t == T:
        return O.forward_ops(params, mdl.head, x, "head/")
    for u in range(t + 1, T + 1):
        x = O.forward_ops(params, mdl.surrogates[u - 1], x, f"s{u}/")
    # gap + the output module's own linear θ_L:
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["op/fc/w"] + params["op/fc/b"]


def submodel_shapes(mdl: ModelDef, t: int) -> InSpec:
    """Parameter inventory for the step-t sub-model (grow or shrink)."""
    T = mdl.num_blocks
    spec = InSpec()
    shapes: dict[str, tuple[int, ...]] = {}
    for u in range(1, t + 1):
        shapes.update(O.param_shapes(mdl.blocks[u - 1], mdl.block_prefix(u)))
    if t == T:
        shapes.update(O.param_shapes(mdl.head, "head/"))
    else:
        for u in range(t + 1, T + 1):
            shapes.update(O.param_shapes(mdl.surrogates[u - 1], f"s{u}/"))
        c_last = mdl.block_out_hwc(T)[2]
        shapes["op/fc/w"] = (c_last, mdl.cfg.num_classes)
        shapes["op/fc/b"] = (mdl.cfg.num_classes,)
    spec.shapes = shapes
    frozen_pref = tuple(mdl.block_prefix(u) for u in range(1, t))
    for name in _ordered(shapes):
        (spec.frozen if name.startswith(frozen_pref) else spec.trainable).append(name)
    return spec


# ---------------------------------------------------------------------------
# Loss / step helpers
# ---------------------------------------------------------------------------


def _ce_loss(logits: jax.Array, y: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _correct(logits: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))


def _sgd_scan(loss_fn, trainable: dict, frozen: dict, xs, ys, lr):
    """S steps of plain SGD over stacked batches via lax.scan.

    Plain (momentum-free) local SGD is the FedAvg-standard client
    optimizer and keeps executable I/O to parameters only.
    """

    def step(tr, batch):
        x, y = batch
        (loss, corr), grads = jax.value_and_grad(loss_fn, has_aux=True)(tr, frozen, x, y)
        tr = jax.tree.map(lambda p, g: p - lr * g, tr, grads)
        return tr, (loss, corr)

    trainable, (losses, corrs) = jax.lax.scan(step, trainable, (xs, ys))
    return trainable, jnp.mean(losses), jnp.sum(corrs)


def _pack(names: list[str], arrays: tuple) -> dict[str, jax.Array]:
    return dict(zip(names, arrays))


# ---------------------------------------------------------------------------
# Artifact graph builders
# ---------------------------------------------------------------------------


def make_train_step(mdl: ModelDef, t: int):
    """Step-t sub-model training (ProFL grow & shrink share this graph)."""
    spec = submodel_shapes(mdl, t)

    def loss_fn(tr, fr, x, y):
        params = {**tr, **fr}
        h = _forward_blocks(mdl, params, x, t)
        logits = _forward_output_module(mdl, params, h, t)
        return _ce_loss(logits, y), _correct(logits, y)

    def fn(*args):
        nt, nf = len(spec.trainable), len(spec.frozen)
        tr = _pack(spec.trainable, args[:nt])
        fr = _pack(spec.frozen, args[nt : nt + nf])
        xs, ys, lr = args[nt + nf :]
        tr, loss, corr = _sgd_scan(loss_fn, tr, fr, xs, ys, lr)
        return tuple(tr[n] for n in spec.trainable) + (loss, corr)

    return fn, spec


def make_train_full(mdl: ModelDef):
    """Full-model end-to-end training (ExclusiveFL, HeteroFL and AllSmall
    width variants use this on their respective ModelCfg)."""
    T = mdl.num_blocks
    spec = submodel_shapes(mdl, T)
    spec.trainable = spec.trainable + spec.frozen  # everything updates
    spec.frozen = []

    def loss_fn(tr, fr, x, y):
        h = _forward_blocks(mdl, tr, x, T)
        logits = O.forward_ops(tr, mdl.head, h, "head/")
        return _ce_loss(logits, y), _correct(logits, y)

    def fn(*args):
        nt = len(spec.trainable)
        tr = _pack(spec.trainable, args[:nt])
        xs, ys, lr = args[nt:]
        tr, loss, corr = _sgd_scan(loss_fn, tr, {}, xs, ys, lr)
        return tuple(tr[n] for n in spec.trainable) + (loss, corr)

    return fn, spec


def make_distill_step(mdl: ModelDef, t: int):
    """§3.2 *Map*: distill trained block t into its surrogate θ_{t,Conv}.

    trainable = surrogate-t params; frozen = blocks 1..t (prefix feeds the
    data forward, block t produces the target features). MSE objective.
    """
    assert 2 <= t <= mdl.num_blocks, "block 1 is never replaced by a surrogate"
    spec = InSpec()
    shapes: dict[str, tuple[int, ...]] = {}
    shapes.update(O.param_shapes(mdl.surrogates[t - 1], f"s{t}/"))
    spec.trainable = _ordered(shapes)
    fro: dict[str, tuple[int, ...]] = {}
    for u in range(1, t + 1):
        fro.update(O.param_shapes(mdl.blocks[u - 1], mdl.block_prefix(u)))
    spec.frozen = _ordered(fro)
    shapes.update(fro)
    spec.shapes = shapes

    def loss_fn(tr, fr, x, _y):
        a = _forward_blocks(mdl, fr, x, t - 1)
        target = O.forward_ops(fr, mdl.blocks[t - 1], a, mdl.block_prefix(t))
        pred = O.forward_ops(tr, mdl.surrogates[t - 1], a, f"s{t}/")
        return jnp.mean((pred - jax.lax.stop_gradient(target)) ** 2), jnp.float32(0.0)

    def fn(*args):
        nt, nf = len(spec.trainable), len(spec.frozen)
        tr = _pack(spec.trainable, args[:nt])
        fr = _pack(spec.frozen, args[nt : nt + nf])
        xs, lr = args[nt + nf :]
        ys = jnp.zeros(xs.shape[:2], jnp.int32)  # unused by the MSE loss
        tr, loss, _ = _sgd_scan(loss_fn, tr, fr, xs, ys, lr)
        return tuple(tr[n] for n in spec.trainable) + (loss,)

    return fn, spec


def make_eval_sub(mdl: ModelDef, t: int):
    """Step-t sub-model evaluation (Fig 4/5 curves, Table 3 rows); at
    t == T this is full-model evaluation."""
    spec = submodel_shapes(mdl, t)
    names = spec.trainable + spec.frozen  # single ordered list for eval
    order = _ordered(spec.shapes)

    def fn(*args):
        params = _pack(order, args[: len(order)])
        x, y = args[len(order) :]
        h = _forward_blocks(mdl, params, x, t)
        logits = _forward_output_module(mdl, params, h, t)
        loss = _ce_loss(logits, y) * x.shape[0]  # sum-form for exact averaging
        return loss, _correct(logits, y)

    eval_spec = InSpec(trainable=[], frozen=order, shapes=spec.shapes)
    return fn, eval_spec


# ---------------------------------------------------------------------------
# DepthFL (baseline): depth-d prefix + per-block classifiers + self-distill
# ---------------------------------------------------------------------------


def depthfl_shapes(mdl: ModelDef, d: int) -> InSpec:
    spec = InSpec()
    shapes: dict[str, tuple[int, ...]] = {}
    for u in range(1, d + 1):
        shapes.update(O.param_shapes(mdl.blocks[u - 1], mdl.block_prefix(u)))
        c = mdl.block_out_hwc(u)[2]
        shapes[f"cls{u}/fc/w"] = (c, mdl.cfg.num_classes)
        shapes[f"cls{u}/fc/b"] = (mdl.cfg.num_classes,)
    spec.shapes = shapes
    spec.trainable = _ordered(shapes)
    return spec


def _depthfl_logits(mdl: ModelDef, params, x, d: int) -> list[jax.Array]:
    outs = []
    h = x
    for u in range(1, d + 1):
        h = O.forward_ops(params, mdl.blocks[u - 1], h, mdl.block_prefix(u))
        feat = jnp.mean(h, axis=(1, 2))
        outs.append(feat @ params[f"cls{u}/fc/w"] + params[f"cls{u}/fc/b"])
    return outs


def make_depthfl_train(mdl: ModelDef, d: int, kd_weight: float = 0.3):
    """DepthFL local objective: Σ_i CE(cls_i) + mutual self-distillation
    (KL of each classifier against the stop-gradient consensus)."""
    spec = depthfl_shapes(mdl, d)

    def loss_fn(tr, fr, x, y):
        logits = _depthfl_logits(mdl, tr, x, d)
        ce = sum(_ce_loss(lg, y) for lg in logits) / len(logits)
        kd = jnp.float32(0.0)
        if len(logits) > 1:
            probs = [jax.nn.softmax(lg) for lg in logits]
            consensus = jax.lax.stop_gradient(sum(probs) / len(probs))
            for lg in logits:
                logp = jax.nn.log_softmax(lg)
                kd += -jnp.mean(jnp.sum(consensus * logp, axis=1))
            kd = kd / len(logits)
        return ce + kd_weight * kd, _correct(logits[-1], y)

    def fn(*args):
        nt = len(spec.trainable)
        tr = _pack(spec.trainable, args[:nt])
        xs, ys, lr = args[nt:]
        tr, loss, corr = _sgd_scan(loss_fn, tr, {}, xs, ys, lr)
        return tuple(tr[n] for n in spec.trainable) + (loss, corr)

    return fn, spec


def make_depthfl_eval(mdl: ModelDef):
    """DepthFL global inference: ensemble (mean softmax) of all T
    classifiers — the paper evaluates DepthFL this way (Table 1 note)."""
    T = mdl.num_blocks
    spec = depthfl_shapes(mdl, T)
    order = _ordered(spec.shapes)

    def fn(*args):
        params = _pack(order, args[: len(order)])
        x, y = args[len(order) :]
        logits = _depthfl_logits(mdl, params, x, T)
        probs = sum(jax.nn.softmax(lg) for lg in logits) / len(logits)
        logp = jnp.log(probs + 1e-9)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1)) * x.shape[0]
        corr = jnp.sum((jnp.argmax(probs, axis=1) == y).astype(jnp.float32))
        return loss, corr

    return fn, InSpec(trainable=[], frozen=order, shapes=spec.shapes)
