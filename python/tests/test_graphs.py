"""L2 graph-builder semantics: freezing, training-makes-progress, spec
partitioning — the contracts the Rust coordinator relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import graphs
from compile.models import ModelCfg, build
from compile import ops as O

CFG = ModelCfg("resnet18", 8, 10)


@pytest.fixture(scope="module")
def mdl():
    return build(CFG)


def init_for(spec, seed=0):
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in spec.shapes.items():
        key, sub = jax.random.split(key)
        if name.endswith("/scale"):
            params[name] = jnp.ones(shape)
        elif name.endswith(("/shift", "/b")):
            params[name] = jnp.zeros(shape)
        else:
            fan_in = int(np.prod(shape[:-1]))
            params[name] = jax.random.normal(sub, shape) * np.sqrt(2.0 / fan_in)
    return params


def fake_batches(seed=0, steps=2, batch=8, structured=True):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    ys = jax.random.randint(ky, (steps, batch), 0, 10)
    xs = jax.random.normal(kx, (steps, batch, 32, 32, 3)) * 0.3
    if structured:
        # class-dependent mean so the task is learnable
        xs = xs + ys[..., None, None, None].astype(jnp.float32) * 0.3
    return xs, ys


# ---------------------------------------------------------------------------
# submodel_shapes partitioning
# ---------------------------------------------------------------------------


def test_submodel_spec_partition(mdl):
    T = mdl.num_blocks
    for t in range(1, T + 1):
        spec = graphs.submodel_shapes(mdl, t)
        # frozen = exactly blocks 1..t-1
        for n in spec.frozen:
            assert any(n.startswith(f"b{u}/") for u in range(1, t)), (t, n)
        # trainable = block t + output module (or head at T)
        for n in spec.trainable:
            ok = n.startswith(f"b{t}/") or n.startswith(("op/", "head/")) or any(
                n.startswith(f"s{u}/") for u in range(t + 1, T + 1)
            )
            assert ok, (t, n)
        if t < T:
            assert "op/fc/w" in spec.trainable
            assert not any(n.startswith("head/") for n in spec.trainable)
        else:
            assert "head/fc/w" in spec.trainable
            assert not any(n.startswith("s") and "/conv" in n for n in spec.trainable)


def test_submodel_t4_equals_full_params(mdl):
    spec = graphs.submodel_shapes(mdl, 4)
    names = set(spec.trainable) | set(spec.frozen)
    assert all(n.startswith(("b1/", "b2/", "b3/", "b4/", "head/")) for n in names)


# ---------------------------------------------------------------------------
# train step semantics
# ---------------------------------------------------------------------------


def test_train_step_decreases_loss(mdl):
    fn, spec = graphs.make_train_step(mdl, 1)
    fn = jax.jit(fn)
    params = init_for(spec)
    xs, ys = fake_batches(steps=2)
    losses = []
    for it in range(8):
        args = [params[n] for n in spec.trainable] + [params[n] for n in spec.frozen] + [xs, ys, jnp.float32(0.05)]
        out = fn(*args)
        for i, n in enumerate(spec.trainable):
            params[n] = out[i]
        losses.append(float(out[-2]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_train_step_frozen_not_returned(mdl):
    fn, spec = graphs.make_train_step(mdl, 3)
    assert len(spec.frozen) > 0
    out_names = spec.trainable + ["loss", "correct"]
    params = init_for(spec)
    xs, ys = fake_batches(steps=1)
    out = fn(*([params[n] for n in spec.trainable] + [params[n] for n in spec.frozen] + [xs, ys, jnp.float32(0.1)]))
    assert len(out) == len(out_names)


def test_train_step_lr_zero_is_identity(mdl):
    fn, spec = graphs.make_train_step(mdl, 2)
    params = init_for(spec)
    xs, ys = fake_batches(steps=1)
    out = fn(*([params[n] for n in spec.trainable] + [params[n] for n in spec.frozen] + [xs, ys, jnp.float32(0.0)]))
    for i, n in enumerate(spec.trainable):
        np.testing.assert_allclose(out[i], params[n], rtol=0, atol=0)


def test_train_full_updates_everything(mdl):
    fn, spec = graphs.make_train_full(mdl)
    assert spec.frozen == []
    params = init_for(spec)
    xs, ys = fake_batches(steps=1)
    out = fn(*([params[n] for n in spec.trainable] + [xs, ys, jnp.float32(0.1)]))
    changed = sum(
        1
        for i, n in enumerate(spec.trainable)
        if not np.allclose(out[i], params[n])
    )
    # every conv/dense weight must move (scale/shift may have tiny grads)
    assert changed > len(spec.trainable) * 0.8


def test_distill_reduces_mse(mdl):
    fn, spec = graphs.make_distill_step(mdl, 2)
    fn = jax.jit(fn)
    params = init_for(spec, seed=3)
    xs, _ = fake_batches(steps=2)
    losses = []
    for it in range(20):
        out = fn(*([params[n] for n in spec.trainable] + [params[n] for n in spec.frozen] + [xs, jnp.float32(0.3)]))
        for i, n in enumerate(spec.trainable):
            params[n] = out[i]
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] * 0.9, losses
    assert all(b <= a * 1.02 for a, b in zip(losses, losses[1:])), losses


def test_eval_sub_counts_bounded(mdl):
    fn, spec = graphs.make_eval_sub(mdl, 2)
    params = init_for(spec)
    xs, ys = fake_batches(steps=1, batch=16)
    loss_sum, correct = fn(*([params[n] for n in spec.frozen] + [xs[0], ys[0]]))
    assert 0 <= float(correct) <= 16
    assert float(loss_sum) > 0


def test_grow_and_shrink_share_graph(mdl):
    """The same executable serves both stages: calling train_t with a
    different frozen-prefix value changes outputs but not structure."""
    fn, spec = graphs.make_train_step(mdl, 2)
    p1 = init_for(spec, seed=0)
    p2 = init_for(spec, seed=9)
    xs, ys = fake_batches(steps=1)
    o1 = fn(*([p1[n] for n in spec.trainable] + [p1[n] for n in spec.frozen] + [xs, ys, jnp.float32(0.1)]))
    o2 = fn(*([p1[n] for n in spec.trainable] + [p2[n] for n in spec.frozen] + [xs, ys, jnp.float32(0.1)]))
    assert not np.allclose(o1[-2], o2[-2])  # prefix matters


# ---------------------------------------------------------------------------
# DepthFL graphs
# ---------------------------------------------------------------------------


def test_depthfl_shapes_nested(mdl):
    s1 = graphs.depthfl_shapes(mdl, 1)
    s4 = graphs.depthfl_shapes(mdl, 4)
    assert set(s1.shapes) < set(s4.shapes)
    assert "cls1/fc/w" in s1.shapes and "cls4/fc/w" in s4.shapes


def test_depthfl_train_and_eval(mdl):
    fn, spec = graphs.make_depthfl_train(mdl, 2)
    params = init_for(spec)
    xs, ys = fake_batches(steps=1)
    out = fn(*([params[n] for n in spec.trainable] + [xs, ys, jnp.float32(0.05)]))
    assert float(out[-2]) > 0
    fe, se = graphs.make_depthfl_eval(mdl)
    pe = init_for(se)
    loss_sum, correct = fe(*([pe[n] for n in se.frozen] + [xs[0], ys[0]]))
    assert 0 <= float(correct) <= xs.shape[1]
