"""Memory-model invariants + manifest integrity (the L3 contract)."""

import json
import os

import pytest

from compile import graphs, memory
from compile.models import ModelCfg, build

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def mdl():
    return build(ModelCfg("resnet18", 8, 10))


# ---------------------------------------------------------------------------
# Memory model invariants (what makes the paper's Fig 6 shape)
# ---------------------------------------------------------------------------


def test_freezing_reduces_memory(mdl):
    """Peak memory of every ProFL step must be below full-model training —
    the paper's headline (up to 57.4% reduction)."""
    full = memory.train_full_mem(mdl).bytes_at(32)
    for t in range(1, 5):
        step = memory.train_step_mem(mdl, t).bytes_at(32)
        assert step < full, t


def test_early_blocks_cost_most_activation_memory(mdl):
    """Fig 6: the 1st block dominates activation memory even though it has
    the fewest parameters."""
    m1 = memory.train_step_mem(mdl, 1)
    m4 = memory.train_step_mem(mdl, 4)
    assert m1.per_sample_bytes > m4.per_sample_bytes
    assert m1.params_trainable < m4.params_trainable


def test_peak_reduction_magnitude(mdl):
    """ProFL's peak across steps should cut ≥40% vs full training at the
    paper's batch size (paper: up to 57.4%)."""
    full = memory.train_full_mem(mdl).bytes_at(32)
    peak = max(memory.train_step_mem(mdl, t).bytes_at(32) for t in range(1, 5))
    assert peak < 0.65 * full, (peak, full)


def test_output_layer_mem_smallest(mdl):
    op = memory.output_layer_mem(mdl).bytes_at(32)
    b1 = memory.train_step_mem(mdl, 1).bytes_at(32)
    assert op < b1


def test_eval_mem_below_train(mdl):
    spec = graphs.submodel_shapes(mdl, 4)
    ev = memory.eval_mem(mdl, spec).bytes_at(32)
    tr = memory.train_full_mem(mdl).bytes_at(32)
    assert ev < tr


def test_depthfl_first_block_heavier_than_profl_step1(mdl):
    """§4.2: DepthFL's smallest model (depth 1) still trains block 1 without
    freezing — ProFL step 1 costs the same or less, later steps much less."""
    d1 = memory.depthfl_mem(mdl, 1).bytes_at(32)
    p4 = memory.train_step_mem(mdl, 4).bytes_at(32)
    assert p4 < d1 or p4 < memory.depthfl_mem(mdl, 4).bytes_at(32)


def test_mem_coeffs_linear(mdl):
    m = memory.train_step_mem(mdl, 2)
    assert m.bytes_at(64) - m.bytes_at(32) == 32 * m.per_sample_bytes


# ---------------------------------------------------------------------------
# Manifest integrity (requires `make artifacts` to have run)
# ---------------------------------------------------------------------------

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_structure():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    assert man["train_batch"] > 0 and man["scan_steps"] > 0
    assert len(man["models"]) >= 1
    for tag, m in man["models"].items():
        assert m["num_blocks"] == len(m["block_param_counts"])
        assert len(m["block_params"]) == m["num_blocks"]
        for name, art in m["artifacts"].items():
            path = os.path.join(ART, art["path"])
            assert os.path.exists(path), path
            assert art["kind"] in ("train", "distill", "eval")
            roles = [i["role"] for i in art["inputs"]]
            if art["kind"] == "train":
                assert roles.count("lr") == 1 and "data_x" in roles and "data_y" in roles
                n_tr = sum(1 for r in roles if r == "trainable")
                assert art["outputs"][:n_tr] == [
                    i["name"] for i in art["inputs"] if i["role"] == "trainable"
                ]
                assert art["outputs"][-2:] == ["loss", "correct"]
            if art["kind"] == "eval":
                assert art["outputs"] == ["loss_sum", "correct"]
            assert "mem" in art or art["kind"] == "eval"


@needs_artifacts
def test_manifest_trainable_roundtrip_order():
    """Input trainable order must equal output order — Rust updates its
    store positionally."""
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    m = man["models"]["resnet18_w8_c10"]
    art = m["artifacts"]["train_t2"]
    tr_in = [i["name"] for i in art["inputs"] if i["role"] == "trainable"]
    assert art["outputs"][: len(tr_in)] == tr_in
    # step-2 trainables are block 2 + output module, frozen is block 1
    assert all(n.startswith(("b2/", "s3/", "s4/", "op/")) for n in tr_in)
    fr = [i["name"] for i in art["inputs"] if i["role"] == "frozen"]
    assert all(n.startswith("b1/") for n in fr)


@needs_artifacts
def test_manifest_params_cover_artifacts():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for tag, m in man["models"].items():
        declared = set(m["params"])
        for art in m["artifacts"].values():
            for i in art["inputs"]:
                if i["role"] in ("trainable", "frozen", "param"):
                    assert i["name"] in declared, (tag, i["name"])
