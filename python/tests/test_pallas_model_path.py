"""Backend invariance: the full L2 model must produce (near-)identical
results whether convs run through XLA-native conv or the Pallas
im2col+GEMM kernel — the guarantee that lets the table benches use the
fast native path while the Pallas path stays the documented L1 artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import graphs
from compile.kernels import conv as kconv
from compile.models import ModelCfg, build


@pytest.fixture(scope="module")
def setup():
    mdl = build(ModelCfg("resnet18", 8, 10))
    fn, spec = graphs.make_train_step(mdl, 1)
    key = jax.random.PRNGKey(0)
    params = {}
    for name, shape in spec.shapes.items():
        key, sub = jax.random.split(key)
        if name.endswith("/scale"):
            params[name] = jnp.ones(shape)
        elif name.endswith(("/shift", "/b")):
            params[name] = jnp.zeros(shape)
        else:
            fan_in = int(np.prod(shape[:-1]))
            params[name] = jax.random.normal(sub, shape) * np.sqrt(2.0 / fan_in)
    xs = jax.random.normal(key, (2, 8, 32, 32, 3)) * 0.5
    ys = jax.random.randint(key, (2, 8), 0, 10)
    args = (
        [params[n] for n in spec.trainable]
        + [params[n] for n in spec.frozen]
        + [xs, ys, jnp.float32(0.05)]
    )
    return mdl, fn, spec, args


def _run(fn, args, backend):
    kconv.set_default_backend(backend)
    try:
        return fn(*args)
    finally:
        kconv.set_default_backend("native")


def test_train_step_backend_invariant(setup):
    """One full fwd+bwd+SGD step: losses and updated parameters must agree
    between backends to f32 tolerance."""
    _mdl, fn, spec, args = setup
    out_native = _run(fn, args, "native")
    out_pallas = _run(fn, args, "pallas")
    # loss / correct
    np.testing.assert_allclose(out_native[-2], out_pallas[-2], rtol=2e-3, atol=2e-3)
    assert float(out_native[-1]) == float(out_pallas[-1])
    # every updated parameter
    for i, name in enumerate(spec.trainable):
        np.testing.assert_allclose(
            out_native[i], out_pallas[i], rtol=5e-3, atol=5e-3, err_msg=name
        )


def test_eval_backend_invariant(setup):
    mdl, _fn, _spec, _args = setup
    fe, se = graphs.make_eval_sub(mdl, 1)
    key = jax.random.PRNGKey(3)
    params = {}
    for name, shape in se.shapes.items():
        key, sub = jax.random.split(key)
        if name.endswith("/scale"):
            params[name] = jnp.ones(shape)
        elif name.endswith(("/shift", "/b")):
            params[name] = jnp.zeros(shape)
        else:
            params[name] = jax.random.normal(sub, shape) * 0.1
    x = jax.random.normal(key, (16, 32, 32, 3))
    y = jax.random.randint(key, (16,), 0, 10)
    args = [params[n] for n in se.frozen] + [x, y]
    ln, cn = _run(fe, args, "native")
    lp, cp = _run(fe, args, "pallas")
    np.testing.assert_allclose(ln, lp, rtol=2e-3, atol=2e-3)
    assert float(cn) == float(cp)
