"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

The hypothesis sweeps are the contract: any (shape, dtype, seed) drawn here
must agree with ref.py to float32 tolerance. These tests gate `make test`
before artifacts are trusted.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv, fused, ref
from compile.kernels.matmul import matmul, matmul_grad, mxu_utilization, vmem_bytes

TOL = dict(rtol=1e-4, atol=1e-4)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref_sweep(m, k, n, seed):
    a = rand(seed, (m, k))
    b = rand(seed + 1, (k, n))
    np.testing.assert_allclose(matmul(a, b), ref.matmul_ref(a, b), **TOL)


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 128), (130, 257, 65)])
def test_matmul_tile_multiples_and_ragged(shape):
    m, k, n = shape
    a = rand(0, (m, k))
    b = rand(1, (k, n))
    np.testing.assert_allclose(matmul(a, b), ref.matmul_ref(a, b), **TOL)


def test_matmul_grad_matches_jnp_grads():
    a = rand(2, (33, 47))
    b = rand(3, (47, 21))

    def f_kernel(a, b):
        return jnp.sum(matmul_grad(a, b) ** 2)

    def f_ref(a, b):
        return jnp.sum((a @ b) ** 2)

    ga_k, gb_k = jax.grad(f_kernel, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga_k, ga_r, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gb_k, gb_r, rtol=1e-3, atol=1e-3)


def test_matmul_identity_and_zero():
    a = rand(4, (16, 16))
    eye = jnp.eye(16)
    np.testing.assert_allclose(matmul(a, eye), a, **TOL)
    np.testing.assert_allclose(matmul(a, jnp.zeros((16, 8))), jnp.zeros((16, 8)), **TOL)


def test_vmem_budget_and_mxu_accounting():
    # The default schedule must fit VMEM with big margin and be fully dense
    # at tile multiples.
    assert vmem_bytes() < 16 * 1024 * 1024 / 8
    assert mxu_utilization(256, 256, 256) == 1.0
    assert 0 < mxu_utilization(130, 130, 130) < 1.0


# ---------------------------------------------------------------------------
# Fused epilogues
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(1, 200),
    c=st.integers(1, 64),
    seed=st.integers(0, 2**16),
)
def test_scale_shift_relu_sweep(rows, c, seed):
    x = rand(seed, (rows, c))
    sc = rand(seed + 1, (c,))
    sh = rand(seed + 2, (c,))
    np.testing.assert_allclose(
        fused.scale_shift_relu(x, sc, sh), ref.scale_shift_relu_ref(x, sc, sh), **TOL
    )


def test_scale_shift_relu_4d_and_grads():
    x = rand(5, (2, 9, 9, 12))
    sc = rand(6, (12,))
    sh = rand(7, (12,))
    np.testing.assert_allclose(
        fused.scale_shift_relu_grad(x, sc, sh),
        ref.scale_shift_relu_ref(x, sc, sh),
        **TOL,
    )
    g_k = jax.grad(lambda x, sc, sh: jnp.sum(fused.scale_shift_relu_grad(x, sc, sh) ** 2), (0, 1, 2))(x, sc, sh)
    g_r = jax.grad(lambda x, sc, sh: jnp.sum(ref.scale_shift_relu_ref(x, sc, sh) ** 2), (0, 1, 2))(x, sc, sh)
    for k, r in zip(g_k, g_r):
        np.testing.assert_allclose(k, r, rtol=1e-3, atol=1e-3)


def test_residual_add_relu_matches_and_grads():
    x = rand(8, (3, 8, 8, 16))
    s = rand(9, (3, 8, 8, 16))
    np.testing.assert_allclose(
        fused.residual_add_relu(x, s), ref.residual_add_relu_ref(x, s), **TOL
    )
    g_k = jax.grad(lambda x, s: jnp.sum(fused.residual_add_relu_grad(x, s) ** 2), (0, 1))(x, s)
    g_r = jax.grad(lambda x, s: jnp.sum(ref.residual_add_relu_ref(x, s) ** 2), (0, 1))(x, s)
    for k, r in zip(g_k, g_r):
        np.testing.assert_allclose(k, r, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Convolution (im2col + GEMM vs lax.conv)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 3),
    hw=st.sampled_from([4, 8, 16, 32]),
    ci=st.integers(1, 8),
    co=st.integers(1, 8),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_conv_pallas_matches_native_sweep(n, hw, ci, co, k, stride, seed):
    x = rand(seed, (n, hw, hw, ci))
    w = rand(seed + 1, (k, k, ci, co))
    np.testing.assert_allclose(
        conv.conv2d_pallas(x, w, stride=stride),
        ref.conv2d_ref(x, w, stride=stride, padding="SAME"),
        rtol=1e-3,
        atol=1e-3,
    )


def test_im2col_patches_equals_conv():
    x = rand(10, (2, 16, 16, 4))
    w = rand(11, (3, 3, 4, 6))
    patches = ref.im2col_patches(x, 3, 3, 1)
    out = (patches @ w.reshape(-1, 6)).reshape(2, 16, 16, 6)
    np.testing.assert_allclose(out, ref.conv2d_ref(x, w, stride=1), rtol=1e-4, atol=1e-4)


def test_conv_grad_through_pallas():
    x = rand(12, (2, 8, 8, 3))
    w = rand(13, (3, 3, 3, 4))
    gk = jax.grad(lambda w: jnp.sum(conv.conv2d_pallas(x, w) ** 2))(w)
    gr = jax.grad(lambda w: jnp.sum(ref.conv2d_ref(x, w) ** 2))(w)
    np.testing.assert_allclose(gk, gr, rtol=1e-3, atol=1e-3)


def test_backend_dispatch_roundtrip():
    assert conv.get_default_backend() == "native"
    conv.set_default_backend("pallas")
    try:
        x = rand(14, (1, 8, 8, 2))
        w = rand(15, (3, 3, 2, 2))
        np.testing.assert_allclose(
            conv.conv2d(x, w), ref.conv2d_ref(x, w), rtol=1e-3, atol=1e-3
        )
    finally:
        conv.set_default_backend("native")


def test_maxpool_and_gap_refs():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    pooled = ref.max_pool_2x2_ref(x)
    assert pooled.shape == (1, 2, 2, 1)
    assert float(pooled[0, 0, 0, 0]) == 5.0
    g = ref.global_avg_pool_ref(x)
    assert g.shape == (1, 1)
    assert float(g[0, 0]) == pytest.approx(7.5)
