"""L2 model-zoo structure tests: Table 5 parameter counts must be exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models, ops as O
from compile.models import ModelCfg, build, block_param_counts, model_param_shapes


# ---------------------------------------------------------------------------
# Table 5 — per-block parameter quantity/percentage at paper width (64)
# ---------------------------------------------------------------------------


def test_table5_resnet18_exact():
    mdl = build(ModelCfg("resnet18", 64, 10))
    counts = block_param_counts(mdl)
    assert [round(c / 1e6, 2) for c in counts] == [0.15, 0.53, 2.10, 8.39]
    total = sum(counts)
    pct = [round(c / total * 100, 1) for c in counts]
    assert pct == [1.3, 4.7, 18.8, 75.2]
    assert round(total / 1e6, 1) == 11.2


def test_table5_resnet34_exact():
    mdl = build(ModelCfg("resnet34", 64, 10))
    counts = block_param_counts(mdl)
    assert round(sum(counts) / 1e6, 2) == 21.28
    pct = [round(c / sum(counts) * 100, 1) for c in counts]
    # paper: 1.0/5.2/32.1/61.6 (their Block1 rounds to 0.22M)
    assert pct[2] == 32.1 and pct[3] == 61.6


@pytest.mark.parametrize("fam,T", [("resnet18", 4), ("resnet34", 4), ("vgg11", 2), ("vgg16", 3)])
def test_block_counts_per_family(fam, T):
    mdl = build(ModelCfg(fam, 16, 10))
    assert mdl.num_blocks == T
    assert len(mdl.surrogates) == T
    assert mdl.surrogates[0] is None
    assert all(s is not None for s in mdl.surrogates[1:])


@pytest.mark.parametrize("fam", models.FAMILIES)
def test_forward_shapes(fam):
    cfg = ModelCfg(fam, 8, 10)
    mdl = build(cfg)
    shapes = model_param_shapes(mdl)
    key = jax.random.PRNGKey(0)
    params = {}
    for t, blk in enumerate(mdl.blocks, 1):
        params.update(O.init_ops(key, blk, mdl.block_prefix(t)))
    params.update(O.init_ops(key, mdl.head, "head/"))
    x = jnp.zeros((2, 32, 32, 3))
    for t, blk in enumerate(mdl.blocks, 1):
        x = O.forward_ops(params, blk, x, mdl.block_prefix(t))
        assert x.shape[1:] == mdl.block_out_hwc(t), (fam, t)
    logits = O.forward_ops(params, mdl.head, x, "head/")
    assert logits.shape == (2, 10)


def test_width_ratio_scales_channels():
    full = build(ModelCfg("resnet18", 8, 10))
    half = build(ModelCfg("resnet18", 8, 10, width_ratio=0.5))
    cf = block_param_counts(full)
    ch = block_param_counts(half)
    assert all(h < f for h, f in zip(ch, cf))
    # Every half-model param must be a leading-corner slice of the full one.
    sf = model_param_shapes(full)
    sh = model_param_shapes(half)
    assert set(sh) == set(sf)
    for name in sf:
        assert all(a <= b for a, b in zip(sh[name], sf[name])), name


def test_surrogate_maps_block_geometry():
    mdl = build(ModelCfg("resnet18", 8, 10))
    for t in range(2, 5):
        sur = mdl.surrogates[t - 1]
        in_hwc = mdl.block_in_hwc(t)
        out = O.analyze_ops(sur, in_hwc).out_hwc
        assert out == mdl.block_out_hwc(t), t


def test_vgg_paper_modifications():
    # VGG11: pool after every 2 convs -> 32/2^4 = 2 spatial; VGG16: every 4 -> 4.
    v11 = build(ModelCfg("vgg11", 64, 10))
    assert v11.block_out_hwc(2)[:2] == (2, 2)
    v16 = build(ModelCfg("vgg16", 64, 10))
    assert v16.block_out_hwc(3)[:2] == (4, 4)
    # single linear classifier
    head_shapes = O.param_shapes(v16.head, "head/")
    assert list(head_shapes) == ["head/fc/w", "head/fc/b"]


def test_init_ops_statistics():
    mdl = build(ModelCfg("resnet18", 16, 10))
    params = O.init_ops(jax.random.PRNGKey(1), mdl.blocks[0], "b1/")
    for name, v in params.items():
        if name.endswith("/scale"):
            assert np.all(np.asarray(v) == 1.0)
        elif name.endswith(("/shift", "/b")):
            assert np.all(np.asarray(v) == 0.0)
        else:
            fan_in = np.prod(v.shape[:-1])
            std = float(np.std(np.asarray(v)))
            assert 0.2 * np.sqrt(2 / fan_in) < std < 3 * np.sqrt(2 / fan_in), name


def test_analyze_ops_flops_positive_and_monotone():
    mdl = build(ModelCfg("resnet18", 8, 10))
    st1 = O.analyze_ops(mdl.blocks[0], (32, 32, 3))
    st4 = O.analyze_ops(mdl.blocks[3], mdl.block_in_hwc(4))
    assert st1.flops_per_sample > 0 and st4.flops_per_sample > 0
    # early blocks dominate activations, late blocks dominate params
    assert st1.stored_act_per_sample > st4.stored_act_per_sample
    assert st1.params < st4.params
