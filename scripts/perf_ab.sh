#!/usr/bin/env bash
# Warmup-aware A/B harness around benches/fleet_scale.rs.
#
# Runs the bench once with a *pinned* warmup (identical cold-path
# treatment on every invocation, so two runs of this script are directly
# comparable), then reports the two A/B matrices the sharded-merge work
# cares about straight from the fresh JSON document:
#
#   serial-vs-sharded : mean-ns speedup of every threads=N row over its
#                       threads=1 twin, fleet rows and merge rows alike
#   pooled-vs-cloning : merge-pooled vs merge-cloning per thread column —
#                       wall-time ratio plus allocs/round reduction
#
# With --baseline FILE it finishes by delegating to perf_compare.sh,
# optionally gated with --max-regress PCT (CI runs this advisory-only).
#
# usage: scripts/perf_ab.sh [--smoke] [--warmup N] [--out FILE]
#                           [--baseline FILE] [--max-regress PCT]
set -euo pipefail

smoke=""
warmup=3
out=BENCH_fleet.json
baseline=""
max_regress=""
while [[ $# -gt 0 ]]; do
    case $1 in
        --smoke) smoke=1; shift ;;
        --warmup) warmup=$2; shift 2 ;;
        --out) out=$2; shift 2 ;;
        --baseline) baseline=$2; shift 2 ;;
        --max-regress) max_regress=$2; shift 2 ;;
        *) echo "unknown option $1" >&2; exit 2 ;;
    esac
done

bench_args=(--warmup "$warmup" --json "$out")
if [[ -n "$smoke" ]]; then
    bench_args+=(--smoke)
fi
cargo bench --bench fleet_scale -- "${bench_args[@]}"

python3 - "$out" <<'PY'
import json
import sys

with open(sys.argv[1]) as f:
    doc = json.load(f)
if doc.get("bench") != "fleet_scale":
    sys.exit(f"{sys.argv[1]}: not a fleet_scale document")

entries = {}
for e in doc["entries"]:
    key = (int(e["fleet"]), e["policy"], e["churn"], int(e.get("threads", 1)))
    entries[key] = e

print("\nA/B: serial-vs-sharded (speedup of threads=N over threads=1)")
for (fleet, policy, churn, threads), e in sorted(entries.items()):
    if threads == 1:
        continue
    base = entries.get((fleet, policy, churn, 1))
    if not base or not e["mean_ns"]:
        continue
    s = base["mean_ns"] / e["mean_ns"]
    print(f"  fleet={fleet:>9} {policy:<13} {churn:<6} threads={threads}: {s:.2f}x")

merge_threads = sorted(
    {t for (_, p, _, t) in entries if p == "merge-pooled"}
)
if merge_threads:
    print("\nA/B: pooled-vs-cloning (cohort-merge rows)")
for t in merge_threads:
    pooled = next(e for (f, p, c, th), e in entries.items()
                  if p == "merge-pooled" and th == t)
    cloning = next(e for (f, p, c, th), e in entries.items()
                   if p == "merge-cloning" and th == t)
    ratio = cloning["mean_ns"] / pooled["mean_ns"] if pooled["mean_ns"] else 0.0
    pa, ca = pooled.get("allocs_per_round"), cloning.get("allocs_per_round")
    allocs = "-" if pa is None or ca is None else f"{ca:.0f} -> {pa:.0f}"
    print(f"  threads={t}: cloning/pooled wall {ratio:.2f}x, allocs/round {allocs}")
PY

if [[ -n "$baseline" ]]; then
    compare_args=("$baseline" "$out")
    if [[ -n "$max_regress" ]]; then
        compare_args+=(--max-regress "$max_regress")
    fi
    "$(dirname "$0")/perf_compare.sh" "${compare_args[@]}"
fi
