#!/usr/bin/env bash
# Before/after comparison of two fleet_scale BENCH_fleet.json documents.
#
# Typical workflow around a perf-sensitive change:
#
#   make bench-json && cp BENCH_fleet.json /tmp/before.json
#   # ... apply the change ...
#   make bench-json
#   scripts/perf_compare.sh /tmp/before.json BENCH_fleet.json
#
# Entries are matched on (fleet, policy, churn, threads); the report
# shows per-entry mean-ns deltas plus allocation-counter drift, and the
# thread-matrix speedup (threads=1 vs each other column) for both files.
# Exits non-zero when --max-regress PCT is given and any matched entry's
# mean regresses by more than PCT percent.
set -euo pipefail

if [[ $# -lt 2 ]]; then
    echo "usage: $0 BEFORE.json AFTER.json [--max-regress PCT]" >&2
    exit 2
fi
before=$1
after=$2
max_regress=${4:-}
if [[ "${3:-}" != "--max-regress" && -n "${3:-}" ]]; then
    echo "unknown option ${3}" >&2
    exit 2
fi

python3 - "$before" "$after" "${max_regress:-}" <<'PY'
import json
import sys

before_path, after_path, max_regress = sys.argv[1], sys.argv[2], sys.argv[3]
limit = float(max_regress) if max_regress else None


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("bench") != "fleet_scale":
        sys.exit(f"{path}: not a fleet_scale document")
    entries = {}
    for e in doc["entries"]:
        # threads was introduced with schema 2; older files are the
        # single-threaded engine, so default the key to 1.
        key = (int(e["fleet"]), e["policy"], e["churn"], int(e.get("threads", 1)))
        entries[key] = e
    return doc, entries


bdoc, b = load(before_path)
adoc, a = load(after_path)
for tag, doc, path in [("before", bdoc, before_path), ("after", adoc, after_path)]:
    runner = doc.get("runner", "?")
    note = "" if runner == "native" else "  ** NOT native Rust numbers **"
    print(f"{tag:>6}: {path} (runner={runner}, schema={doc.get('schema')}){note}")
print()

shared = sorted(set(b) & set(a))
if not shared:
    sys.exit("no matching (fleet, policy, churn, threads) entries between the two files")
only_b = sorted(set(b) - set(a))
only_a = sorted(set(a) - set(b))

print(f"{'fleet':>9} {'policy':<12} {'churn':<8} {'thr':>3} "
      f"{'before ns':>12} {'after ns':>12} {'delta':>8}  allocs/round")
worst = None
for key in shared:
    fleet, policy, churn, threads = key
    bm, am = b[key]["mean_ns"], a[key]["mean_ns"]
    delta = (am - bm) / bm * 100.0 if bm else 0.0
    if worst is None or delta > worst[0]:
        worst = (delta, key)
    # Allocator columns are null in twin-produced files (only the native
    # bench's counting allocator can fill them).
    def allocs(e):
        v = e.get("allocs_per_round")
        return "-" if v is None else f"{v:.0f}"

    print(f"{fleet:>9} {policy:<12} {churn:<8} {threads:>3} "
          f"{bm:>12.0f} {am:>12.0f} {delta:>+7.1f}%  {allocs(b[key])} -> {allocs(a[key])}")

for tag, entries in [("before", b), ("after", a)]:
    speedups = []
    for (fleet, policy, churn, threads), e in sorted(entries.items()):
        if threads == 1:
            continue
        base = entries.get((fleet, policy, churn, 1))
        if base and e["mean_ns"]:
            speedups.append((fleet, policy, churn, threads,
                             base["mean_ns"] / e["mean_ns"]))
    if speedups:
        print(f"\n{tag}: thread-matrix speedup vs threads=1")
        for fleet, policy, churn, threads, s in speedups:
            print(f"  fleet={fleet:>9} {policy:<12} {churn:<8} "
                  f"threads={threads}: {s:.2f}x")

if only_b:
    print(f"\nonly in before: {len(only_b)} entries")
if only_a:
    print(f"only in after:  {len(only_a)} entries")

if limit is not None and worst and worst[0] > limit:
    delta, key = worst
    sys.exit(f"\nFAIL: {key} regressed {delta:+.1f}% (> {limit}%)")
PY
