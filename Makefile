# Top-level convenience targets. `make check` is the pre-PR gate
# (fmt + clippy + tests); see ROADMAP.md.

.PHONY: check artifacts

check:
	./rust/check.sh

# AOT-lower the JAX/Pallas models to HLO artifacts consumed by the Rust
# runtime (L2/L1; see python/compile). The `compile` package lives under
# python/; its default --out-dir already resolves to ./artifacts here.
artifacts:
	cd python && python -m compile.aot
