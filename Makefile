# Top-level convenience targets. `make check` is the pre-PR gate
# (fmt + clippy + tests); see ROADMAP.md.

.PHONY: check docs artifacts test-golden test-golden-update smoke-examples \
        bench-json bench-json-smoke perf-ab telemetry-smoke strategy-smoke \
        resume-smoke test-resume

check:
	./rust/check.sh

# API docs with warnings-as-errors: the crate carries
# #![warn(missing_docs)], so an undocumented public item (or a broken
# intra-doc link) fails the build. Part of `make check` via check.sh.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p profl

# Golden-trace regression tests only (fleet simulator event traces,
# compared bit-for-bit against rust/tests/golden/). Regenerate with
# `make test-golden-update` after an intentional engine change and
# review the diff.
test-golden:
	cargo test --test golden_trace

test-golden-update:
	UPDATE_GOLDEN=1 cargo test --test golden_trace
	git diff --stat rust/tests/golden/

# Artifact-free example smoke runs (CI uses this so examples can't
# bit-rot; async_vs_sync skips cleanly when artifacts are absent).
smoke-examples:
	cargo run --release --example churn_sweep -- --smoke
	cargo run --release --example async_vs_sync -- --profile smoke

# Structured-telemetry smoke gate: emit a JSONL stream + manifest.json
# from an artifact-free fleet run, then re-parse and validate both
# in-process (the binary exits non-zero on any contract violation; see
# docs/OBSERVABILITY.md).
telemetry-smoke:
	cargo run --release --example telemetry_tour -- --smoke

# Strategy-zoo smoke gate: enumerate every MemoryStrategy schedule,
# assert the ProFL/ParamAware trait port reproduces the legacy schedule
# phase-for-phase, and drive all four strategies head-to-head through
# the fleet engine with footprint/dispatch self-validation (the binary
# exits non-zero on any violation; see docs/STRATEGIES.md).
strategy-smoke:
	cargo run --release --example strategy_zoo -- --smoke

# Checkpoint/resume smoke gate: kill an artifact-free fleet run at every
# round boundary, resume from the on-disk checkpoint, and byte-compare
# against the uninterrupted trace; also proves tampered files and
# drifted configs are rejected (the binary exits non-zero on any
# violation; see docs/CHECKPOINT.md).
resume-smoke:
	cargo run --release --example resume_tour -- --smoke

# The checkpoint/resume test tree: differential golden resume suite,
# codec/pool/engine/strategy property tests, and the adversarial parser
# fuzzer with its regression corpus (rust/tests/corpus/).
test-resume:
	PROFL_THREADS=4 cargo test -q --test resume_golden --test fuzz_inputs --test proptests
	PROFL_THREADS=4 cargo test -q --test integration resume

# Fleet-scale perf trajectory: run the artifact-free round-scheduling
# bench across fleet sizes (1e3 → 1e6) × planner threads (1/4/8) and
# write BENCH_fleet.json at the repo root — per-round ns plus allocation
# counters, comparable across PRs (see docs/PERFORMANCE.md for schema +
# interpretation; `scripts/perf_compare.sh` diffs two such files). The
# smoke variant is CI-sized (1e3, 1e4).
bench-json:
	cargo bench --bench fleet_scale -- --json BENCH_fleet.json

bench-json-smoke:
	cargo bench --bench fleet_scale -- --smoke --json BENCH_fleet.json

# Warmup-pinned A/B report (serial-vs-sharded speedups and the
# pooled-vs-cloning merge columns) over a fresh bench run; pass
# BASELINE=FILE to also diff against a prior BENCH_fleet.json via
# scripts/perf_compare.sh (see docs/PERFORMANCE.md).
perf-ab:
	scripts/perf_ab.sh --smoke $(if $(BASELINE),--baseline $(BASELINE))

# AOT-lower the JAX/Pallas models to HLO artifacts consumed by the Rust
# runtime (L2/L1; see python/compile). The `compile` package lives under
# python/; its default --out-dir already resolves to ./artifacts here.
artifacts:
	cd python && python -m compile.aot
